"""Multi-level checkpoint storage engine (shared by the FTI/SCR/VeloC
backends — they differ in API surface and feature set, not in plumbing).

Levels (paper §4.2.1 / FTI semantics):
  L1  node-local write (RAM-disk / NVMe analogue)
  L2  L1 + partner copy on a different node
  L3  L1 + Reed–Solomon (or XOR) parity across the node group
  L4  parallel-file-system write (global directory)

Restart search order: L1 → L2 (partner) → L3 (erasure reconstruct) → L4,
newest checkpoint id first — exactly FTI's recovery ladder.

All writes go through the manifest commit protocol (atomic rename); payloads
are CHK5 containers, so every checkpoint is also an analyzable dataset
(§4.2.4).
"""
from __future__ import annotations

import io
import os
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import manifest as mf
from repro.core.comm import Communicator
from repro.core.diff import (
    DiffEngine,
    LeafDelta,
    apply_delta,
    dtype_str,
    leaf_to_u32_flat,
    str_dtype,
    u32_flat_to_leaf,
)
from repro.core.formats import CHK5CorruptionError, CHK5Reader, CHK5Writer
from repro.redundancy import erasure
from repro.redundancy.groups import Topology
from repro.redundancy.partner import (
    find_partner_copy,
    partner_tag,
    replicate,
    store_partner_copy,
)

CHK_FULL = "FULL"
CHK_DIFF = "DIFF"


@dataclass
class StorageConfig:
    root: str                                  # base dir for this run
    block_bytes: int = 65_536
    keep_last_full: int = 2
    group_size: int = 4
    erasure_scheme: str = "rs"                 # "rs" | "xor"
    rs_parity: int = 2
    promote_threshold: float = 0.95            # diff→full break-even (Fig. 7)
    ranks_per_node: int = 1
    custom_groups: Optional[dict] = None       # SCR-style group overrides

    @property
    def global_root(self) -> str:
        return os.path.join(self.root, "global")


@dataclass
class StoreReport:
    ckpt_id: int
    level: int
    kind: str
    bytes_payload: int
    seconds: float
    dirty_ratio: Optional[float] = None
    promoted_full: bool = False


class StorageEngine:
    def __init__(self, cfg: StorageConfig, comm: Communicator):
        self.cfg = cfg
        self.comm = comm
        self.topo = Topology(
            world=comm.world,
            ranks_per_node=cfg.ranks_per_node,
            group_size=min(cfg.group_size, comm.world),
            custom_groups=cfg.custom_groups,
        )
        self.diff = DiffEngine(cfg.block_bytes, cfg.promote_threshold)
        os.makedirs(self.local_root, exist_ok=True)
        os.makedirs(cfg.global_root, exist_ok=True)

    # ------------------------------------------------------------------ #

    @property
    def local_root(self) -> str:
        return os.path.join(self.comm.node_local_dir, "ckpts")

    def _tier_root(self, level: int) -> str:
        return self.cfg.global_root if level >= 4 else self.local_root

    # ------------------------------------------------------------------ #
    # write path
    # ------------------------------------------------------------------ #

    def _serialize_full(self, named: Dict[str, np.ndarray],
                        meta: Dict[str, Any], path: str) -> int:
        with CHK5Writer(path) as w:
            w.set_attrs("", dict(meta, kind=CHK_FULL))
            for name, arr in named.items():
                w.write_dataset(f"data/{name}", np.asarray(arr),
                                {"dtype": dtype_str(arr.dtype)})
        return os.path.getsize(path)

    def _serialize_diff(self, deltas: List[LeafDelta],
                        meta: Dict[str, Any], path: str) -> int:
        with CHK5Writer(path) as w:
            w.set_attrs("", dict(meta, kind=CHK_DIFF))
            for d in deltas:
                g = f"delta/{d.path}"
                w.write_dataset(f"{g}/idx", d.dirty_idx)
                w.write_dataset(f"{g}/blocks", d.payload)
                w.write_dataset(
                    f"{g}/digest", d.digests,
                    {"dtype": d.dtype, "shape": d.shape,
                     "n_blocks": d.n_blocks})
        return os.path.getsize(path)

    def store(self, named_host: Dict[str, np.ndarray], ckpt_id: int,
              level: int, kind: str = CHK_FULL,
              extra_meta: Optional[Dict[str, Any]] = None,
              diff_supported: bool = True) -> StoreReport:
        """Coordinated store of this rank's (host-side) protected data."""
        t0 = time.time()
        level = max(1, min(4, level))
        root = self._tier_root(level)
        meta: Dict[str, Any] = dict(extra_meta or {}, level=level,
                                    rank=self.comm.rank, world=self.comm.world)
        dirty_ratio = None
        promoted = False

        d = mf.begin(root, ckpt_id)
        path = os.path.join(d, f"rank{self.comm.rank}.chk5")

        if kind == CHK_DIFF and not diff_supported:
            kind = CHK_FULL                 # VeloC: no checkpoint kinds (§3)
            meta["diff_fallback"] = True
        if kind == CHK_DIFF:
            deltas, stats = self.diff.compute_deltas(named_host)
            dirty_ratio = stats.dirty_ratio
            if deltas is None:
                kind = CHK_FULL
                promoted = True
            else:
                meta["base_required"] = True
                nbytes = self._serialize_diff(deltas, meta, path)
        if kind == CHK_FULL:
            nbytes = self._serialize_full(named_host, meta, path)
            self.diff.update_digests_full(named_host)

        # redundancy scheme per level
        if level == 2:
            payload = open(path, "rb").read()
            replicate(self.comm, self.topo, ckpt_id, payload)
            self.comm.barrier()
            store_partner_copy(self.comm, self.topo, ckpt_id, d)
        elif level == 3:
            self._erasure_encode(ckpt_id, d, path)

        # commit (rank0-equivalent; every rank writes the same manifest data
        # in the single-process container, idempotent)
        statuses = self.comm.allgather(
            {"rank": self.comm.rank, "ok": True, "file": os.path.basename(path),
             "nbytes": nbytes})
        mf.write_manifest(root, ckpt_id, {
            "kind": kind, "level": level, "world": self.comm.world,
            "group_size": self.topo.group_size,
            "erasure": self.cfg.erasure_scheme,
            "block_bytes": self.cfg.block_bytes,
            "ranks": statuses,
            **(extra_meta or {}),
        })
        mf.commit(root, ckpt_id, keep_last=0)      # pruning handled below
        self._prune_chains(root)
        return StoreReport(ckpt_id, level, kind, nbytes, time.time() - t0,
                           dirty_ratio, promoted)

    # ------------------------------------------------------------------ #

    def _peer_ckpt_dir_for_write(self, rank: int, ckpt_id: int
                                 ) -> Optional[str]:
        """Resolve where a parity shard for ``rank`` should land (its tier
        dir, committed or in-flight)."""
        if rank == self.comm.rank:
            base = self.local_root
        else:
            peer = self.comm.peer_local_dir(rank)
            if peer is None:
                return None
            base = os.path.join(peer, "ckpts")
        final = mf.ckpt_dir(base, ckpt_id)
        tmp = mf.ckpt_dir(base, ckpt_id, tmp=True)
        return final if os.path.isdir(final) else (
            tmp if os.path.isdir(tmp) else None)

    def _erasure_encode(self, ckpt_id: int, d: str, path: str) -> None:
        """Erasure-encode across the node group.

        Every member posts its payload to the whole group; whichever member
        observes the complete set (in MPI: after the group barrier; in the
        sequential test cluster: the last member to store) computes the
        parity shards and places shard j on group[j % |group|]'s tier.
        """
        import json
        group = self.topo.erasure_group(self.comm.rank)
        g = self.topo.group_index(self.comm.rank)
        payload = open(path, "rb").read()
        for r in group:
            if r != self.comm.rank:
                self.comm.post(f"er:{ckpt_id}", r, payload)
        self.comm.barrier()
        blobs = [
            payload if r == self.comm.rank
            else self.comm.collect(f"er:{ckpt_id}", r)
            for r in group
        ]
        if any(b is None for b in blobs):
            return                  # not complete yet (an earlier member)
        lengths = [len(b) for b in blobs]
        if self.cfg.erasure_scheme == "xor":
            parities = [erasure.encode_xor(blobs)]
        else:
            parities = erasure.encode_rs(
                blobs, min(self.cfg.rs_parity, len(group)))
        meta = json.dumps({"lengths": lengths, "group": group})
        for j, par in enumerate(parities):
            # parity placement: on the NEXT group's nodes (ring) so a single
            # node loss never takes a payload and its covering parity
            # together; single-group worlds fall back to in-group rotation
            # (then XOR needs rs/m ≥ 2 to survive a parity-holder loss)
            if self.comm.world > len(group):
                holder = (group[-1] + 1 + j) % self.comm.world
            else:
                holder = group[(j + 1) % len(group)]
            hd = d if holder == self.comm.rank else \
                self._peer_ckpt_dir_for_write(holder, ckpt_id)
            if hd is None:
                hd = d              # fall back: keep shard locally
            with open(os.path.join(hd, f"parity.g{g}.p{j}.bin"), "wb") as f:
                f.write(par)
            with open(os.path.join(hd, f"parity.g{g}.meta"), "w") as f:
                f.write(meta)
        with open(os.path.join(d, f"parity.g{g}.meta"), "w") as f:
            f.write(meta)

    # ------------------------------------------------------------------ #
    # retention: keep the last N FULLs plus the diff chain above them
    # ------------------------------------------------------------------ #

    def _prune_chains(self, root: str) -> None:
        ids = mf.list_committed(root)
        fulls = [i for i in ids
                 if mf.read_manifest(root, i).get("kind") == CHK_FULL]
        keep_from = fulls[-self.cfg.keep_last_full] if len(
            fulls) >= self.cfg.keep_last_full else (fulls[0] if fulls else None)
        if keep_from is None:
            return
        for i in ids:
            if i < keep_from:
                import shutil
                shutil.rmtree(mf.ckpt_dir(root, i), ignore_errors=True)

    # ------------------------------------------------------------------ #
    # read path
    # ------------------------------------------------------------------ #

    def available_ids(self) -> List[Tuple[int, str]]:
        """All committed checkpoint ids across tiers → [(id, tier_root)].
        Includes reachable peers' node-local tiers (a restarted rank on a
        fresh node recovers from partner/parity held by survivors)."""
        roots = [self.local_root, self.cfg.global_root]
        for r in range(self.comm.world):
            if r == self.comm.rank:
                continue
            peer = self.comm.peer_local_dir(r)
            if peer is not None:
                roots.append(os.path.join(peer, "ckpts"))
        out = []
        for root in roots:
            for i in mf.list_committed(root):
                out.append((i, root))
        return sorted(out)

    def _peer_ckpt_dirs(self, ckpt_id: int):
        """This tier's checkpoint dir on every reachable node (recovery may
        pull partner replicas / parity from surviving nodes' local storage)."""
        dirs = []
        for r in range(self.comm.world):
            if r == self.comm.rank:
                base = self.local_root
            else:
                peer = self.comm.peer_local_dir(r)
                if peer is None:
                    continue
                base = os.path.join(peer, "ckpts")
            d = mf.ckpt_dir(base, ckpt_id)
            if os.path.isdir(d):
                dirs.append(d)
        return dirs

    def _rank_payload(self, root: str, ckpt_id: int, rank: int
                      ) -> Optional[bytes]:
        """Fetch rank payload, falling back to partner / erasure recovery."""
        p = os.path.join(mf.ckpt_dir(root, ckpt_id), f"rank{rank}.chk5")
        if os.path.exists(p):
            try:
                CHK5Reader(p).close()
                return open(p, "rb").read()
            except CHK5CorruptionError:
                pass
        # search this node's dir plus reachable peers (L2 replicas / L3 parity
        # live on *other* nodes' local storage)
        search = [mf.ckpt_dir(root, ckpt_id)]
        if root != self.cfg.global_root:
            search += [d for d in self._peer_ckpt_dirs(ckpt_id)
                       if d not in search]
        for d in search:
            p = os.path.join(d, f"rank{rank}.chk5")
            if os.path.exists(p):
                try:
                    CHK5Reader(p).close()
                    return open(p, "rb").read()
                except CHK5CorruptionError:
                    continue
            pc = find_partner_copy(self.topo, d, rank)
            if pc:
                return open(pc, "rb").read()
        # L3 erasure reconstruct across the surviving group files
        try:
            man = mf.read_manifest(root, ckpt_id)
        except OSError:
            man = {}
        if man.get("level") == 3:
            return self._erasure_reconstruct_multi(search, rank)
        return None

    def _erasure_reconstruct_multi(self, dirs, rank: int) -> Optional[bytes]:
        """Reconstruct ``rank``'s payload from survivors + parity scattered
        across the given checkpoint dirs (one per reachable node)."""
        import json
        group = self.topo.erasure_group(rank)
        g = self.topo.group_index(rank)

        def find(name: str) -> Optional[str]:
            for d in dirs:
                p = os.path.join(d, name)
                if os.path.exists(p):
                    return p
            return None

        meta_p = find(f"parity.g{g}.meta")
        if meta_p is None:
            return None
        meta = json.loads(open(meta_p).read())
        lengths = meta["lengths"]
        survivors: Dict[int, bytes] = {}
        for j, r in enumerate(group):
            p = find(f"rank{r}.chk5")
            if p:
                survivors[j] = open(p, "rb").read()
        parities: Dict[int, bytes] = {}
        for j in range(len(group)):        # collect every surviving shard
            p = find(f"parity.g{g}.p{j}.bin")
            if p is not None:
                parities[j] = open(p, "rb").read()
        try:
            if self.cfg.erasure_scheme == "xor":
                blobs = erasure.decode_xor(survivors, parities[0], len(group),
                                           lengths)
            else:
                blobs = erasure.decode_rs(survivors, parities, len(group),
                                          lengths)
        except Exception:
            return None
        return blobs[group.index(rank)]

    def load_latest(self, rank: Optional[int] = None
                    ) -> Optional[Tuple[Dict[str, np.ndarray], Dict[str, Any]]]:
        """Restore newest restorable checkpoint: FULL base + diff replay."""
        rank = self.comm.rank if rank is None else rank
        cands = self.available_ids()
        by_id: Dict[int, List[str]] = {}
        for i, root in cands:
            by_id.setdefault(i, []).append(root)
        for ckpt_id in sorted(by_id, reverse=True):
            got = self._try_restore(ckpt_id, by_id, rank)
            if got is not None:
                return got
        return None

    def _read_payload_any_tier(self, ckpt_id: int, by_id, rank: int
                               ) -> Optional[Tuple[bytes, Dict]]:
        for root in by_id.get(ckpt_id, []):
            blob = self._rank_payload(root, ckpt_id, rank)
            if blob is not None:
                return blob, mf.read_manifest(root, ckpt_id)
        return None

    def _try_restore(self, ckpt_id: int, by_id, rank: int):
        # walk back to the base FULL
        chain: List[Tuple[bytes, Dict]] = []
        cur = ckpt_id
        while True:
            got = self._read_payload_any_tier(cur, by_id, rank)
            if got is None:
                return None
            blob, man = got
            chain.append((blob, man))
            if man.get("kind") == CHK_FULL:
                break
            prev = [i for i in by_id if i < cur]
            if not prev:
                return None
            cur = max(prev)
        chain.reverse()                     # [full, diff, diff, ...]

        named: Dict[str, np.ndarray] = {}
        flat_u32: Dict[str, np.ndarray] = {}
        meta_shape: Dict[str, Tuple[str, List[int]]] = {}
        bb = None
        for blob, man in chain:
            bb = man.get("block_bytes", self.cfg.block_bytes)
            rd = CHK5Reader(_BytesFile(blob))
            if man.get("kind") == CHK_FULL:
                for ds in rd.datasets():
                    if ds.startswith("data/"):
                        name = ds[len("data/"):]
                        named[name] = rd.read_dataset(ds)
                flat_u32.clear()
            else:
                for ds in rd.datasets():
                    if not ds.endswith("/digest"):
                        continue
                    name = ds[len("delta/"): -len("/digest")]
                    info = rd.info(ds)["attrs"]
                    idx = rd.read_dataset(f"delta/{name}/idx")
                    blocks = rd.read_dataset(f"delta/{name}/blocks")
                    if name not in flat_u32:
                        if name not in named:
                            return None     # chain broken
                        flat_u32[name] = leaf_to_u32_flat(named[name], bb)
                        meta_shape[name] = (info["dtype"], info["shape"])
                    flat_u32[name] = apply_delta(flat_u32[name], idx, blocks, bb)
                    meta_shape[name] = (info["dtype"], info["shape"])
            rd.close()
        for name, buf in flat_u32.items():
            dt, shp = meta_shape[name]
            named[name] = u32_flat_to_leaf(buf, dt, shp)
        final_meta = chain[-1][1]
        return named, final_meta


class _BytesFile(io.BytesIO):
    """CHK5Reader takes a path; give it a seekable in-memory file instead."""

    def __init__(self, data: bytes):
        super().__init__(data)
