"""mixtral-8x7b — sparse MoE decoder, 8 experts top-2, SWA [arXiv:2401.04088; hf]."""
from repro.configs.base import ArchConfig, MoEConfig, register

ARCH = register(ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14_336,
    vocab_size=32_000,
    sliding_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2),
    rope_theta=1_000_000.0,
    source="arXiv:2401.04088; hf",
))
