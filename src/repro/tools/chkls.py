"""``python -m repro.tools.chkls <file.chk5>`` — inspect CHK5 containers.

The paper's HDF5 argument: checkpoints double as analyzable datasets, with
standard tools. This is that tool for CHK5.  Clause-carrying stores
(core/protect.Protect) record their clauses as dataset attributes — the
listing shows the interesting ones (codec, kind, precision, fallbacks) and
``--json`` emits the full machine-readable inventory so CI can assert on
container contents.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from repro.core.formats import CHK5Reader

#: clause/codec attrs worth a column in the human listing
_CLAUSE_ATTRS = ("codec", "kind", "precision", "codec_fallback",
                 "precision_fallback")


def _clause_str(name: str, attrs: dict) -> str:
    parts = []
    if name.startswith("shardidx/"):
        # the shard index of a sharded leaf: summarize the chunk set so the
        # listing shows where the payload actually lives
        parts.append(f"sharded n_chunks={attrs.get('n_chunks')} "
                     f"global={tuple(attrs.get('global_shape', ()))} "
                     f"over {len(set(attrs.get('files', [])))} file(s)")
    for k in _CLAUSE_ATTRS:
        if k in attrs:
            parts.append(f"{k}={attrs[k]}")
    return " ".join(parts)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="list CHK5 checkpoint contents")
    ap.add_argument("file")
    ap.add_argument("--verify", action="store_true", help="check all crc32s")
    ap.add_argument("--stats", action="store_true",
                    help="per-dataset min/max/mean for float data")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable inventory (attrs included)")
    args = ap.parse_args(argv)

    rd = CHK5Reader(args.file, verify=args.verify)

    if args.as_json:
        datasets = []
        for name in rd.datasets():
            m = rd.info(name)
            datasets.append({"name": name, "dtype": m["dtype"],
                             "shape": list(m["shape"]),
                             "nbytes": m["nbytes"],
                             "attrs": m.get("attrs", {})})
        inv = {
            "file": args.file,
            "attrs": rd.attrs(""),
            "datasets": datasets,
            "total_bytes": sum(d["nbytes"] for d in datasets),
            "verified": bool(args.verify),
        }
        print(json.dumps(inv, indent=1, sort_keys=True))
        rd.close()
        return 0

    root_attrs = rd.attrs("")
    if root_attrs:
        print(f"attrs: {root_attrs}")
    total = 0
    for name in rd.datasets():
        m = rd.info(name)
        total += m["nbytes"]
        line = (f"  {name:60s} {m['dtype']:>10s} "
                f"{str(tuple(m['shape'])):>20s} {m['nbytes']:>12,d} B")
        clauses = _clause_str(name, m.get("attrs", {}))
        if clauses:
            line += f"  [{clauses}]"
        if args.stats and m["dtype"] != "bytes":
            try:
                a = rd.read_dataset(name).astype(np.float32)
                if a.size:
                    line += (f"  [{a.min():+.3e}, {a.max():+.3e}]"
                             f" μ={a.mean():+.3e}")
            except (TypeError, ValueError):
                pass
        print(line)
    print(f"{len(rd.datasets())} datasets, {total:,} bytes"
          + ("  (crc OK)" if args.verify else ""))
    rd.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
