"""Regenerate analytic fields inside existing dry-run JSONs (keeps the
compile-derived memory/HLO diagnostics) and emit the EXPERIMENTS.md tables.

  PYTHONPATH=src python -m repro.roofline.report refresh     # update JSONs
  PYTHONPATH=src python -m repro.roofline.report tables      # print tables
"""
from __future__ import annotations

import glob
import json
import sys
from typing import Dict, List

from repro.configs import SHAPE_BY_NAME, get_arch
from repro.roofline.analytic import analytic_report


def refresh(pattern: str = "reports/dryrun/*.json") -> int:
    n = 0
    for p in sorted(glob.glob(pattern)):
        with open(p) as f:
            r = json.load(f)
        if r.get("status") != "ok":
            continue
        knobs = r.get("knobs", {})
        dp = 32 if r["mesh"] == "2x16x16" else 16
        tp = 16
        if knobs.get("dp_only"):
            dp, tp = dp * tp, 1
        import dataclasses
        cfg = get_arch(r["arch"])
        if knobs.get("param_dtype") and knobs["param_dtype"] != cfg.param_dtype:
            cfg = dataclasses.replace(cfg, param_dtype=knobs["param_dtype"])
        if knobs.get("moe_dispatch") and cfg.moe is not None:
            cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
                cfg.moe, dispatch=knobs["moe_dispatch"]))
        ana = analytic_report(
            cfg, SHAPE_BY_NAME[r["shape"]], dp=dp, tp=tp,
            remat=knobs.get("remat", True), zero1=knobs.get("zero1", False),
            fsdp=knobs.get("fsdp", False))
        r.update(ana)
        with open(p, "w") as f:
            json.dump(r, f, indent=1, default=float)
        n += 1
    print(f"refreshed {n} reports")
    return 0


def _fmt(x, w=9, p=4):
    return f"{x:{w}.{p}f}"


def tables(pattern: str = "reports/dryrun/*.json") -> int:
    rows: List[Dict] = []
    for p in sorted(glob.glob(pattern)):
        rows.append(json.load(open(p)))
    for mesh_tag, title in (("16x16", "single-pod 16×16 (256 chips)"),
                            ("2x16x16", "multi-pod 2×16×16 (512 chips)")):
        print(f"\n### Roofline — {title}\n")
        print("| arch | shape | t_compute s | t_memory s | t_collective s |"
              " bound | useful | roofline frac | peak mem/dev |")
        print("|---|---|---|---|---|---|---|---|---|")
        for r in rows:
            if r.get("mesh") != mesh_tag and not (
                    r.get("status") == "skipped" and mesh_tag == "16x16"
                    and r.get("mesh", "16x16") == "16x16"):
                continue
            if r.get("status") == "skipped":
                print(f"| {r['arch']} | {r['shape']} | — | — | — | N/A "
                      f"(skip: full attention) | — | — | — |")
                continue
            mem = r.get("peak_memory_per_device")
            mem_s = f"{mem / 2**30:.1f} GiB" if mem else "—"
            print(f"| {r['arch']} | {r['shape']} | {r['t_compute']:.4f} "
                  f"| {r['t_memory']:.4f} | {r['t_collective']:.4f} "
                  f"| {r['bottleneck']} | {r['useful_flops_ratio']:.3f} "
                  f"| {r['roofline_fraction']:.3f} | {mem_s} |")
    return 0


if __name__ == "__main__":
    cmd = sys.argv[1] if len(sys.argv) > 1 else "tables"
    raise SystemExit(refresh() if cmd == "refresh" else tables())
