"""Incremental checkpointing — the paper's §8 Future Work, implemented.

    "a checkpoint is not fully written at one time, but incrementally
     built in several separated write operations that are performed as
     soon as the data is ready […] forces, then velocities, then the
     positions. Overall, all the variables are checkpointed, but the
     write operations are separated in time, to decrease storage
     congestion and maximize parallelization."

The training-loop analogue: gradients→optimizer-moments→params become
valid at different points inside a step (and per layer under pipelining);
each part ships as soon as it is ready instead of as one burst.

API (directive-style)::

    inc = ctx.store_begin(id=step, level=2)     # opens the checkpoint
    inc.add(grads_part,  prefix="opt")          # as soon as it's ready
    inc.add(new_params,  prefix="params")
    inc.commit()                                 # manifest + redundancy

An incremental store is a pipeline store whose Pack stage is spread over
time by the caller: ``add`` appends parts to the staged container, and
``commit`` runs the ordinary Place → Commit tail — so level-2/3
incremental checkpoints get exactly the same partner/erasure redundancy as
monolithic ones, and on a backend with a CP-dedicated thread the tail runs
asynchronously (``commit`` then returns None; errors surface at the next
directive, like any async store).

The container stays uncommitted (``.tmp``) until ``commit``; a crash
mid-build leaves no restorable-but-partial checkpoint (same atomicity as
regular stores — tests/test_incremental.py).
"""
from __future__ import annotations

import os
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core import manifest as mf
from repro.core.formats import CHK5Writer, dtype_to_str
from repro.core.protect import flatten_named, to_host
from repro.core.storage import CHK_FULL, StorageEngine, StoreReport


class IncrementalStore:
    def __init__(self, engine: StorageEngine, ckpt_id: int, level: int,
                 extra_meta: Optional[Dict[str, Any]] = None,
                 cp=None, stats: Optional[Dict[str, Any]] = None):
        self.engine = engine
        self.pipeline = engine.pipeline
        self.ckpt_id = ckpt_id
        self.extra_meta = dict(extra_meta or {})
        self._cp = cp                       # backend's CP-dedicated thread
        self._stats = stats
        self._t0 = time.time()
        self._plan = self.pipeline.plan_external(
            ckpt_id, level, extra_meta=dict(self.extra_meta,
                                            incremental=True))
        self.level = self._plan.level
        d = mf.begin(self._plan.root, ckpt_id)
        self._path = os.path.join(d, f"rank{engine.comm.rank}.chk5")
        self._writer = CHK5Writer(self._path)
        self._writer.set_attrs("", dict(self.extra_meta, kind=CHK_FULL,
                                        incremental=True))
        self._names: List[str] = []
        self._named_all: Dict[str, np.ndarray] = {}
        self._committed = False

    def add(self, subtree: Any, prefix: str = "") -> "IncrementalStore":
        """Write one part now (device→host snapshot + append to container)."""
        assert not self._committed, "incremental store already committed"
        named, _ = flatten_named(subtree)
        host = to_host(named)
        for name, arr in host.items():
            full = f"{prefix}/{name}" if prefix else name
            if full in self._named_all:
                raise ValueError(f"part {full!r} written twice")
            self._writer.write_dataset(
                f"data/{full}", np.asarray(arr),
                {"dtype": dtype_to_str(arr.dtype),
                 "part_time": time.time() - self._t0})
            self._named_all[full] = arr
            self._names.append(full)
        return self

    def abort(self) -> None:
        if not self._committed:
            self._writer.close()
            mf.abort(self._plan.root, self.ckpt_id)
            self._committed = True

    def commit(self) -> Optional[StoreReport]:
        """Close the container, then run the pipeline's Place → Commit tail
        (level redundancy + atomic manifest commit).

        Synchronous backend: returns the StoreReport.  With a CP-dedicated
        thread the tail runs asynchronously and commit returns None."""
        assert not self._committed
        if self._cp is not None:
            # surface deferred failures BEFORE closing the writer or
            # touching the digest chain: on raise, this store stays
            # uncommitted and commit() can be retried
            self._cp.check_errors()
        self._writer.close()
        self._committed = True
        nbytes = os.path.getsize(self._path)
        # digest coherence for subsequent CHK_DIFF stores — on the calling
        # thread, so an immediately following DIFF plan sees this base
        self.pipeline.diff.update_digests_full(self._named_all)
        plan = self._plan
        plan.extra["parts"] = list(self._names)
        # report seconds = build time (begin→commit) + tail work, but not
        # time spent waiting in the CP queue behind other stores
        plan.plan_seconds = time.time() - self._t0

        def tail() -> StoreReport:
            rep = self.pipeline.finish_external(plan, self._path, nbytes)
            if self._stats is not None:
                self._stats["stores"] += 1
                self._stats["bytes"] += rep.bytes_payload
            return rep

        if self._cp is not None:
            self._cp.submit(self.ckpt_id, tail)
            return None
        return tail()
