"""Heat-2D against the native VeloC-style API (memory mode): mem_protect
registration, restart_test/restart protocol, explicit waits (paper Fig. 15,
Table 6)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.apps.heat2d_common import checksum, heat_step, init_grid
from repro.backends.veloc import VELOC_FAILURE, VELOC_SUCCESS, VeloCBackend  # [CR]
from repro.core.comm import LocalComm                                        # [CR]
from repro.core.storage import StorageConfig                                 # [CR]


def run(n=128, steps=200, ckpt_every=20, ckpt_dir="/tmp/heat-veloc",
        injector=None, backend=None):
    grid = init_grid(n)
    t = 0
    vlc = VeloCBackend(StorageConfig(root=ckpt_dir),                        # [CR]
                       LocalComm(ckpt_dir + "/node-local"))                 # [CR]
    vlc.mem_protect(0, np.int32(t), "t")                                    # [CR]
    vlc.mem_protect(1, np.asarray(grid), "grid")                            # [CR]
    restarted = False                                                       # [CR]
    version = vlc.restart_test("heat")              # modified program flow   [CR]
    if version != VELOC_FAILURE:                                            # [CR]
        if vlc.restart("heat", version) != VELOC_SUCCESS:                   # [CR]
            raise RuntimeError("VeloC restart failed")                      # [CR]
        t = int(vlc.recovered(0))                   # manual deserialize      [CR]
        grid = jnp.asarray(vlc.recovered(1))                                # [CR]
        restarted = t > 0                                                   # [CR]
    for step in range(t, steps):
        grid = heat_step(grid)
        if injector is not None:
            injector.maybe_fail(step + 1)
        if (step + 1) % ckpt_every == 0:                                    # [CR]
            vlc.mem_protect(0, np.int32(step + 1), "t")                     # [CR]
            vlc.mem_protect(1, np.asarray(grid), "grid")                    # [CR]
            if vlc.checkpoint("heat", step + 1) != VELOC_SUCCESS:           # [CR]
                raise RuntimeError("VeloC internal error")                  # [CR]
    vlc.checkpoint_wait()                                                   # [CR]
    vlc.tcl_finalize()                                                      # [CR]
    return {"checksum": checksum(grid), "restarted": restarted}
