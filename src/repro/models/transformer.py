"""Decoder-only LM assembly (dense / MoE / MLA / SSM / hybrid layers).

Layer stacks are *stacked along axis 0* and consumed with ``lax.scan`` so the
compiled HLO contains one layer body per distinct layer template regardless
of depth. Hybrid archs (jamba) stack *groups* (one repetition of the layer
pattern) and scan over groups.

Forward signatures:
  ``lm_forward(params, tokens, cfg, extra_embeds=None) → (logits, aux)``
  ``lm_decode_step(params, token, caches, pos, cfg) → (logits, caches)``
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.context import DATA, MODEL, shard_hint
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    cast_floating,
    embed_init,
    init_mlp,
    init_rmsnorm,
    mlp,
    rmsnorm,
)

Params = Dict[str, Any]


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


def _cdt(cfg: ArchConfig):
    return jnp.dtype(cfg.compute_dtype)


# --------------------------------------------------------------------------- #
# per-layer init/apply
# --------------------------------------------------------------------------- #


def _is_moe_layer(cfg: ArchConfig, idx_in_pattern: int) -> bool:
    if cfg.moe is None:
        return False
    return idx_in_pattern % cfg.moe.every_k_layers == (cfg.moe.every_k_layers - 1) \
        if cfg.moe.every_k_layers > 1 else True


def init_layer(key, cfg: ArchConfig, kind: str, moe_layer: bool, dtype) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"ln1": init_rmsnorm(cfg.d_model, dtype)}
    if kind == "attn":
        p["attn"] = attn.init_attention(ks[0], cfg, dtype)
    elif kind == "mamba":
        p["mixer"] = ssm_mod.init_mamba_layer(ks[0], cfg, dtype)
    elif kind == "rwkv6":
        p["mixer"] = ssm_mod.init_rwkv6_layer(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    if kind != "rwkv6":                      # rwkv layer embeds its own ffn
        p["ln2"] = init_rmsnorm(cfg.d_model, dtype)
        if moe_layer:
            p["moe"] = moe_mod.init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
    else:
        p["ln2"] = init_rmsnorm(cfg.d_model, dtype)
    return p


def apply_layer(p: Params, h: jnp.ndarray, cfg: ArchConfig, kind: str,
                moe_layer: bool) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pre-norm residual block → (h, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "attn":
        h = h + attn.attention(
            p["attn"], rmsnorm(p["ln1"], h, cfg.norm_eps), cfg)
    elif kind == "mamba":
        h = h + ssm_mod.mamba_block(p["mixer"], rmsnorm(p["ln1"], h, cfg.norm_eps), cfg)
    elif kind == "rwkv6":
        h = h + ssm_mod.rwkv6_time_mix(p["mixer"], rmsnorm(p["ln1"], h, cfg.norm_eps), cfg)
        h = h + ssm_mod.rwkv6_channel_mix(p["mixer"], rmsnorm(p["ln2"], h, cfg.norm_eps))
        return h, aux
    x = rmsnorm(p["ln2"], h, cfg.norm_eps)
    if moe_layer:
        y, aux = moe_mod.moe_mlp(p["moe"], x, cfg)
    else:
        y = mlp(p["mlp"], x, cfg.act)
    h = h + y
    h = shard_hint(h, DATA, None, None)
    return h, aux


# --------------------------------------------------------------------------- #
# full-model init
# --------------------------------------------------------------------------- #


def _pattern(cfg: ArchConfig) -> Tuple[str, ...]:
    if cfg.hybrid_pattern is not None:
        return cfg.hybrid_pattern
    if cfg.family == "ssm" and cfg.ssm is not None and cfg.ssm.kind == "rwkv6":
        return ("rwkv6",)
    if cfg.family == "ssm":
        return ("mamba",)
    return ("attn",)


def init_lm(key, cfg: ArchConfig) -> Params:
    dtype = _dtype(cfg)
    pattern = _pattern(cfg)
    n_groups = cfg.n_layers // len(pattern)
    assert cfg.n_layers % len(pattern) == 0
    ks = jax.random.split(key, 4)

    def group_init(gkey):
        sub = jax.random.split(gkey, len(pattern))
        return [
            init_layer(sub[i], cfg, pattern[i], _is_moe_layer(cfg, i), dtype)
            for i in range(len(pattern))
        ]

    gkeys = jax.random.split(ks[0], n_groups)
    stacked = jax.vmap(group_init)(gkeys)      # list of stacked layer pytrees

    params: Params = {
        "embed": embed_init(ks[1], cfg.vocab_size, cfg.d_model, dtype),
        "groups": stacked,
        "ln_f": init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(ks[2], cfg.d_model, cfg.vocab_size, dtype)
    return params


def lm_param_struct(cfg: ArchConfig) -> Any:
    """ShapeDtypeStruct pytree without allocating (dry-run path)."""
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(functools.partial(init_lm, cfg=cfg), key)


# --------------------------------------------------------------------------- #
# forward (train / prefill)
# --------------------------------------------------------------------------- #


def lm_backbone(params: Params, h: jnp.ndarray, cfg: ArchConfig,
                remat: bool = False, unroll: bool = False
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """``unroll=True`` emits one HLO body per group instead of a scan —
    used by the roofline calibration (cost_analysis counts while bodies
    once; an unrolled module is loop-free and countable)."""
    pattern = _pattern(cfg)

    def group_body(carry, gp):
        h, aux = carry
        for i, kind in enumerate(pattern):
            h, a = apply_layer(gp[i], h, cfg, kind, _is_moe_layer(cfg, i))
            aux = aux + a
        return (h, aux), None

    body = jax.checkpoint(group_body) if remat else group_body
    carry = (h, jnp.zeros((), jnp.float32))
    if unroll:
        n_groups = jax.tree.leaves(params["groups"])[0].shape[0]
        for g in range(n_groups):
            gp = jax.tree.map(lambda x: x[g], params["groups"])
            carry, _ = body(carry, gp)
        return carry
    (h, aux), _ = jax.lax.scan(body, carry, params["groups"])
    return h, aux


def lm_logits(params: Params, h: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = h @ w.astype(h.dtype)
    return shard_hint(logits, DATA, None, MODEL)


def lm_forward(
    params: Params,
    tokens: jnp.ndarray,                       # (B, S_text) int32
    cfg: ArchConfig,
    *,
    extra_embeds: Optional[jnp.ndarray] = None,  # (B, P, d) prepended (vlm)
    remat: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    cdt = _cdt(cfg)
    params = cast_floating(params, cdt)
    h = params["embed"][tokens].astype(cdt)
    if extra_embeds is not None:
        h = jnp.concatenate([extra_embeds.astype(cdt), h], axis=1)
    h = shard_hint(h, DATA, None, None)
    h, aux = lm_backbone(params, h, cfg, remat=remat)
    return lm_logits(params, h, cfg), aux


# --------------------------------------------------------------------------- #
# decode (one token, stacked caches, scan over groups)
# --------------------------------------------------------------------------- #


class LayerCache(NamedTuple):
    """Per-group cache union; unused fields are shape-(0,) placeholders."""
    attn: Any
    ssm: Any


def init_caches(batch: int, cfg: ArchConfig, max_len: int) -> Any:
    """Stacked (n_groups, ...) cache pytree."""
    cdt = _cdt(cfg)
    pattern = _pattern(cfg)
    n_groups = cfg.n_layers // len(pattern)

    def one_group(_):
        caches = []
        for kind in pattern:
            if kind == "attn":
                caches.append(attn.init_decode_cache(batch, cfg, max_len, cdt))
            elif kind == "mamba":
                caches.append(ssm_mod.init_mamba_state(batch, cfg, cdt))
            elif kind == "rwkv6":
                caches.append(ssm_mod.init_rwkv_state(batch, cfg, cdt))
        return caches

    return jax.vmap(one_group)(jnp.arange(n_groups))


def cache_struct(batch: int, cfg: ArchConfig, max_len: int) -> Any:
    return jax.eval_shape(
        functools.partial(init_caches, batch, cfg, max_len))


def cache_protects(selector: str = "**") -> list:
    """Explicit axis metadata for :func:`init_caches` pytrees, carried as
    ``Protect`` axis clauses: every leaf is stacked ``(n_groups, B, ...)``,
    so batch is dim 1 — no size-match guessing needed
    (``dist/sharding.cache_shardings`` consumes this before falling back
    to its heuristic)."""
    from repro.core.protect import Protect
    return [Protect(selector, axis={"batch": 1})]


def lm_decode_step(
    params: Params,
    token: jnp.ndarray,                        # (B, 1) int32
    caches: Any,
    pos,                                       # scalar int32
    cfg: ArchConfig,
) -> Tuple[jnp.ndarray, Any]:
    cdt = _cdt(cfg)
    params = cast_floating(params, cdt)
    pattern = _pattern(cfg)
    h = params["embed"][token].astype(cdt)

    def group_body(h, xs):
        gp, gcache = xs
        new_caches = []
        for i, kind in enumerate(pattern):
            p = gp[i]
            c = gcache[i]
            if kind == "attn":
                y, c = attn.attention_decode(
                    p["attn"], rmsnorm(p["ln1"], h, cfg.norm_eps), c, pos, cfg)
                h = h + y
            elif kind == "mamba":
                y, c = ssm_mod.mamba_decode_step(
                    p["mixer"], rmsnorm(p["ln1"], h, cfg.norm_eps), c, cfg)
                h = h + y
            elif kind == "rwkv6":
                y, c = ssm_mod.rwkv6_decode_step(
                    p["mixer"], rmsnorm(p["ln1"], h, cfg.norm_eps), c, cfg)
                h = h + y
                y, c = ssm_mod.rwkv6_channel_mix_decode(
                    p["mixer"], rmsnorm(p["ln2"], h, cfg.norm_eps), c)
                h = h + y
            if kind in ("attn", "mamba"):
                x = rmsnorm(p["ln2"], h, cfg.norm_eps)
                if _is_moe_layer(cfg, i):
                    y, _ = moe_mod.moe_mlp(p["moe"], x, cfg)
                else:
                    y = mlp(p["mlp"], x, cfg.act)
                h = h + y
            new_caches.append(c)
        return h, new_caches

    h, new_caches = jax.lax.scan(group_body, h, (params["groups"], caches))
    return lm_logits(params, h, cfg), new_caches
