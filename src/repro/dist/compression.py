"""int8 gradient compression — per-block max-abs scaling.

The 1-bit-Adam/PowerSGD-style bandwidth lever for gradient exchange and
compressed checkpoint payloads: quantize to int8 with one fp32 scale per
``block`` elements. Per-block scaling bounds the elementwise error by
``max|g_block| / 127`` — callers that keep the quantization residual
(error feedback) get unbiased accumulation (asserted in
tests/test_flash_compression.py).

Zero blocks round-trip exactly (scale 0 → payload 0 → dequantized 0).
"""
from __future__ import annotations

import math
from typing import Sequence, Tuple

import jax.numpy as jnp
import numpy as np

BLOCK = 1024


def quantize_int8(g: jnp.ndarray, block: int = BLOCK
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """→ (q int8 ``(n_blocks, block)``, scale fp32 ``(n_blocks,)``)."""
    flat = g.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    safe = jnp.where(scale > 0.0, scale, 1.0)[:, None]
    q = jnp.where(scale[:, None] > 0.0, jnp.round(blocks / safe), 0.0)
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    shape: Sequence[int]) -> jnp.ndarray:
    """Inverse of :func:`quantize_int8` (drops the block padding)."""
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    return flat[: math.prod(shape)].reshape(tuple(shape))


def compress_roundtrip_error(g: jnp.ndarray, block: int = BLOCK) -> jnp.ndarray:
    """Relative L2 round-trip error — the metric the compression tier logs
    to decide whether a payload is worth quantizing."""
    q, s = quantize_int8(g, block)
    back = dequantize_int8(q, s, g.shape)
    denom = jnp.maximum(jnp.linalg.norm(g.reshape(-1)), 1e-12)
    return jnp.linalg.norm((back - g).reshape(-1)) / denom


# -------------------------------------------------------------------------- #
# host-side (numpy) variants — the checkpoint Pack stage runs after the
# device→host snapshot, on the CP-dedicated thread; keep it off the device
# -------------------------------------------------------------------------- #


def quantize_int8_np(a: np.ndarray, block: int = BLOCK
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Numpy mirror of :func:`quantize_int8` for Pack-side payload
    compression (core/tiers.Int8CompressTier).  Bit-identical semantics:
    per-block max-abs scale, zero blocks round-trip exactly.

    One vectorized max-abs/scale pass over the ``(n_blocks, block)``
    reshape; round/clip run in place on the single quotient temporary
    (the old masked-``where`` formulation materialized three extra
    block-matrix temporaries, which dominated the compressed-store
    overhead benchmark)."""
    flat = np.asarray(a).reshape(-1)
    if flat.dtype != np.float32:
        flat = flat.astype(np.float32)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = np.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = np.abs(blocks).max(axis=1)
    scale /= np.float32(127.0)
    safe = np.where(scale > 0.0, scale, np.float32(1.0))
    q = blocks / safe[:, None]
    np.rint(q, out=q)
    np.clip(q, -127.0, 127.0, out=q)
    # blocks whose scale is 0 (all-zero) or NaN quantize to 0, exactly as
    # the jnp version's where(scale > 0, ..., 0) mask does
    q[~(scale > 0.0)] = 0.0
    return q.astype(np.int8), scale


def dequantize_int8_np(q: np.ndarray, scale: np.ndarray,
                       shape: Sequence[int]) -> np.ndarray:
    """Inverse of :func:`quantize_int8_np` (drops the block padding)."""
    out = q.astype(np.float32)
    out *= np.asarray(scale)[:, None]
    return out.reshape(-1)[: math.prod(shape)].reshape(tuple(shape))
