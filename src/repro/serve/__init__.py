"""serve substrate."""
