"""Sequence-sharded KV decode (long-context path): GSPMD's partial-softmax
combine must be numerically identical to single-device decode. Runs in a
subprocess with 8 forced host devices."""
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_arch
    from repro.dist.context import use_mesh
    from repro.models.zoo import build_model

    cfg = get_arch("tinyllama-1.1b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))

    # build a warm cache by decoding 16 tokens on one device
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 17), 0,
                              cfg.vocab_size, jnp.int32)
    caches = m.init_caches(1, 64)
    for i in range(16):
        ref_logits, caches = m.decode_step(params, toks[:, i:i+1], caches,
                                           jnp.int32(i))
    ref_logits, ref_caches = m.decode_step(params, toks[:, 16:17], caches,
                                           jnp.int32(16))

    # now the same step with the KV cache sequence-sharded over 8 devices
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    def shard_cache(leaf):
        # (L, B, C, KV, dh): shard the cache-seq dim (64 % 8 == 0)
        dims = [None] * leaf.ndim
        if leaf.ndim >= 3 and leaf.shape[2] == 64:
            dims[2] = "data"
        return NamedSharding(mesh, P(*dims))
    with use_mesh(mesh):
        cshard = jax.tree.map(shard_cache, caches)
        caches_sharded = jax.tree.map(
            lambda x, s: jax.device_put(x, s), caches, cshard)
        step = jax.jit(m.decode_step,
                       in_shardings=(None, None, cshard, None),
                       out_shardings=(NamedSharding(mesh, P()), cshard))
        got_logits, _ = step(params, toks[:, 16:17], caches_sharded,
                             jnp.int32(16))
        txt = step.lower(params, toks[:, 16:17], caches_sharded,
                         jnp.int32(16)).compile().as_text()

    # bf16 activations + different reduction order across shards ⇒ a few
    # ulps of bf16 at logit scale (~0.003 abs)
    np.testing.assert_allclose(
        np.asarray(got_logits, np.float32),
        np.asarray(ref_logits, np.float32), rtol=5e-2, atol=2e-2)
    # the combine must be reductions (all-reduce), not a 64-token gather
    n_ar = txt.count(" all-reduce(") + txt.count(" all-reduce-start(")
    assert n_ar > 0, "expected partial-softmax all-reduces"
    print("DIST-DECODE-OK all_reduces=", n_ar)
""")


def test_seq_sharded_decode_matches_single_device():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=540, cwd=".")
    assert "DIST-DECODE-OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]
