"""§Perf hillclimb: hypothesis → change → measure → validate, per cell.

Three cells (selection rule from the deliverable):
  A. granite-moe-3b-a800m × train_4k — WORST roofline fraction (0.013);
  B. tinyllama-1.1b × train_4k — most COLLECTIVE-bound (t_coll/t_next max);
  C. jamba-1.5-large-398b × train_4k — most representative of the paper's
     technique (largest checkpoint state: 398B params ⇒ CR cost dominates
     operational behavior; also collective-bound and over HBM at baseline).

Each iteration is a knob set over the analytic cost model (the same model
the dry-run embeds); structural knobs (dp_only / fsdp / zero1 / bf16) are
additionally *compile-verified* on the production mesh via launch/dryrun.
Run:  PYTHONPATH=src python -m repro.roofline.perf_loop [--verify-compiles]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
from typing import Any, Dict, List

from repro.configs import SHAPE_BY_NAME, get_arch
from repro.roofline.analytic import analytic_report


def _run(arch: str, shape: str, dp=16, tp=16, param_dtype=None,
         moe_dispatch=None, **knobs) -> Dict[str, Any]:
    cfg = get_arch(arch)
    if param_dtype:
        cfg = dataclasses.replace(cfg, param_dtype=param_dtype)
    if moe_dispatch and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch=moe_dispatch))
    return analytic_report(cfg, SHAPE_BY_NAME[shape], dp=dp, tp=tp, **knobs)


CELLS: Dict[str, Dict[str, Any]] = {
    "A:granite-moe-3b-a800m/train_4k": {
        "arch": "granite-moe-3b-a800m",
        "shape": "train_4k",
        "why": "worst baseline roofline fraction",
        "iterations": [
            {
                "name": "A1-flash-attention",
                "hypothesis": (
                    "memory term (8.41s) is dominated by attention score "
                    "HBM traffic: 24 heads % 16 ≠ 0 ⇒ heads unshardable on "
                    "the model axis, so every device carries full-head "
                    "score tensors — b16·h24·4096²·4B·2(rw)·4(remat) ≈ "
                    "20.6 TB/dev of the 6.9 TB total is impossible, but "
                    "score+kv streaming is the top byte site; fusing "
                    "attention (Pallas flash kernel, kernels/flashattn.py) "
                    "eliminates the score round-trip entirely"),
                "knobs": {"attn_impl": "flash"},
            },
            {
                "name": "A2-scatter-dispatch",
                "hypothesis": (
                    "fine-grained MoE (E=40, top-8, d_ff=512) pays "
                    "one-hot dispatch einsum flops ≈ group·topk·cf·d per "
                    "token ≈ 33% of expert flops; sort-based scatter "
                    "dispatch moves this to bytes"),
                "knobs": {"attn_impl": "flash", "moe_dispatch": "scatter"},
            },
            {
                "name": "A3-zero1-bf16-int8",
                "hypothesis": (
                    "with memory fixed, the collective term (grad sync "
                    "fp32 over dp=16) is next; bf16 params + ZeRO-1 + int8 "
                    "compressed gradients cut grad wire 8/3 ≈ 2.7×"),
                "knobs": {"attn_impl": "flash", "moe_dispatch": "scatter",
                          "param_dtype": "bfloat16", "zero1": True,
                          "grad_compress": "int8"},
            },
            {
                "name": "A4-overlap-gradsync",
                "hypothesis": (
                    "remaining grad wire can hide under backward compute "
                    "(bucketed async all-reduce); exposed collective time "
                    "→ max(0, t_grad − t_compute)"),
                "knobs": {"attn_impl": "flash", "moe_dispatch": "scatter",
                          "param_dtype": "bfloat16", "zero1": True,
                          "grad_compress": "int8", "overlap_gradsync": True},
            },
            {
                "name": "A5-dp-only",
                "hypothesis": (
                    "still collective-bound: TP psums on d=1536 "
                    "activations — same disease as cell B. 3.3B params at "
                    "bf16 = 6.6GB replicate fine; fold the model axis "
                    "into data (dp=256, tp=1): psums vanish, grad sync "
                    "int8+ZeRO over 256 is cheap"),
                "knobs": {"dp": 256, "tp": 1, "attn_impl": "flash",
                          "moe_dispatch": "scatter",
                          "param_dtype": "bfloat16", "zero1": True,
                          "grad_compress": "int8", "overlap_gradsync": True},
                "verify_compile": ["--dp-only", "--zero1",
                                   "--param-dtype", "bfloat16",
                                   "--moe-dispatch", "scatter"],
            },
        ],
    },
    "B:tinyllama-1.1b/train_4k": {
        "arch": "tinyllama-1.1b",
        "shape": "train_4k",
        "why": "most collective-bound cell (t_coll 1.8× next term)",
        "iterations": [
            {
                "name": "B1-dp-only",
                "hypothesis": (
                    "TP=16 Megatron psums on a 1.1B/d=2048 model cost "
                    "~132 psums × 268MB ≈ 66GB wire (1.35s) while the MXU "
                    "work per device is tiny; using the model axis as "
                    "extra data parallelism (dp=256, params replicated) "
                    "removes ALL TP psums for a grad all-reduce of "
                    "2·4.4GB·255/256 ≈ 8.8GB (0.18s) — 7.7× less wire"),
                "knobs": {"dp": 256, "tp": 1},
                "verify_compile": ["--dp-only"],
            },
            {
                "name": "B2-zero1-bf16",
                "hypothesis": (
                    "grad sync now dominates the collective term; bf16 "
                    "params with ZeRO-1 (RS fp32 grads + AG bf16 params) "
                    "cut wire to 6/8 and shard optimizer traffic 256-way"),
                "knobs": {"dp": 256, "tp": 1, "zero1": True,
                          "param_dtype": "bfloat16"},
                "verify_compile": ["--dp-only", "--zero1",
                                   "--param-dtype", "bfloat16"],
            },
            {
                "name": "B3-int8-grads",
                "hypothesis": (
                    "int8 block-quantized gradients with error feedback "
                    "(dist/compression.py) cut the RS payload 4× more: "
                    "wire → P·(1+2)·frac"),
                "knobs": {"dp": 256, "tp": 1, "zero1": True,
                          "param_dtype": "bfloat16", "grad_compress": "int8"},
            },
            {
                "name": "B4-flash-attention",
                "hypothesis": (
                    "collective fixed ⇒ memory-bound on score traffic; "
                    "fused flash attention removes it"),
                "knobs": {"dp": 256, "tp": 1, "zero1": True,
                          "param_dtype": "bfloat16", "grad_compress": "int8",
                          "attn_impl": "flash"},
            },
            {
                "name": "B5-overlap-gradsync",
                "hypothesis": "hide the remaining grad wire under backward",
                "knobs": {"dp": 256, "tp": 1, "zero1": True,
                          "param_dtype": "bfloat16", "grad_compress": "int8",
                          "attn_impl": "flash", "overlap_gradsync": True},
            },
        ],
    },
    "C:jamba-1.5-large-398b/train_4k": {
        "arch": "jamba-1.5-large-398b",
        "shape": "train_4k",
        "why": ("paper-representative: 398B-param checkpoint state (CR cost "
                "is the operational story) + collective-bound + over-HBM "
                "at fp32 baseline"),
        "iterations": [
            {
                "name": "C1-fit-fsdp-zero1-bf16",
                "hypothesis": (
                    "baseline does not fit: fp32 params+moments = "
                    "398e9·12B/16 ≈ 280GB/dev. bf16 params sharded over "
                    "dp too (FSDP) + ZeRO-1 moments: 3.1+12.4 ≈ 15.5GB/dev "
                    "— fits v5e; costs an extra param all-gather per pass"),
                "knobs": {"param_dtype": "bfloat16", "zero1": True,
                          "fsdp": True},
                "verify_compile": ["--fsdp", "--zero1",
                                   "--param-dtype", "bfloat16"],
            },
            {
                "name": "C2-int8-grads",
                "hypothesis": (
                    "grad RS at fp32 (P/16·4B·15/16 ≈ 93GB wire) dominates "
                    "collectives with TP psums; int8 grads cut it 4×"),
                "knobs": {"param_dtype": "bfloat16", "zero1": True,
                          "fsdp": True, "grad_compress": "int8"},
            },
            {
                "name": "C3-overlap-gradsync",
                "hypothesis": ("17.8s of backward compute can hide all "
                               "remaining grad wire"),
                "knobs": {"param_dtype": "bfloat16", "zero1": True,
                          "fsdp": True, "grad_compress": "int8",
                          "overlap_gradsync": True},
            },
            {
                "name": "C4-flash-attention",
                "hypothesis": ("attention layers (9/72) still stream "
                               "scores; flash trims the memory term"),
                "knobs": {"param_dtype": "bfloat16", "zero1": True,
                          "fsdp": True, "grad_compress": "int8",
                          "overlap_gradsync": True, "attn_impl": "flash"},
            },
        ],
    },
}


def run_cell_loop(key: str, spec: Dict[str, Any]) -> Dict[str, Any]:
    arch, shape = spec["arch"], spec["shape"]
    baseline = _run(arch, shape)
    log: List[Dict[str, Any]] = []
    prev = baseline
    for it in spec["iterations"]:
        knobs = dict(it["knobs"])
        dp = knobs.pop("dp", 16)
        tp = knobs.pop("tp", 16)
        pd = knobs.pop("param_dtype", None)
        md = knobs.pop("moe_dispatch", None)
        after = _run(arch, shape, dp=dp, tp=tp, param_dtype=pd,
                     moe_dispatch=md, **knobs)
        dom_before = prev["bottleneck"]
        delta = prev[f"t_{dom_before}"] - after[f"t_{dom_before}"]
        confirmed = after["roofline_fraction"] > prev["roofline_fraction"]
        log.append({
            "name": it["name"],
            "hypothesis": it["hypothesis"],
            "before": {k: prev[k] for k in (
                "t_compute", "t_memory", "t_collective", "bottleneck",
                "roofline_fraction")},
            "after": {k: after[k] for k in (
                "t_compute", "t_memory", "t_collective", "bottleneck",
                "roofline_fraction")},
            "dominant_term_delta_s": delta,
            "confirmed": bool(confirmed),
            "verify_compile": it.get("verify_compile"),
        })
        prev = after
    return {
        "cell": key, "why": spec["why"],
        "baseline": {k: baseline[k] for k in (
            "t_compute", "t_memory", "t_collective", "bottleneck",
            "roofline_fraction", "useful_flops_ratio")},
        "final": {k: prev[k] for k in (
            "t_compute", "t_memory", "t_collective", "bottleneck",
            "roofline_fraction", "useful_flops_ratio")},
        "speedup": (max(baseline["t_compute"], baseline["t_memory"],
                        baseline["t_collective"]) /
                    max(prev["t_compute"], prev["t_memory"],
                        prev["t_collective"])),
        "iterations": log,
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="reports/perf/perf_log.json")
    args = ap.parse_args()
    results = [run_cell_loop(k, v) for k, v in CELLS.items()]
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1, default=float)
    for r in results:
        print(f"\n== {r['cell']} ({r['why']})")
        print(f"   baseline frac={r['baseline']['roofline_fraction']:.3f} "
              f"bound={r['baseline']['bottleneck']}")
        for it in r["iterations"]:
            mark = "✓" if it["confirmed"] else "✗"
            print(f"   {mark} {it['name']:24s} frac "
                  f"{it['before']['roofline_fraction']:.3f} → "
                  f"{it['after']['roofline_fraction']:.3f}  "
                  f"bound {it['before']['bottleneck']}→{it['after']['bottleneck']}")
        print(f"   final frac={r['final']['roofline_fraction']:.3f}  "
              f"speedup ×{r['speedup']:.1f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
