"""Communicator abstraction — the ``comm(...)`` clause of ``chk init``.

Production binding is the jax.distributed process group (rank = process
index over the pod mesh). For this container (1 process) and for unit tests,
``SimulatedCluster`` runs k ranks *in one process* against an in-memory
exchange, so partner-copy and erasure-group logic is exercised for real:
each rank has its own node-local directory; "network" transfers are posts
into the shared exchange.
"""
from __future__ import annotations

import os
import threading
from typing import Any, Callable, Dict, List, Optional



class Communicator:
    """Interface: rank/world + the few collective ops CR needs."""

    rank: int
    world: int
    node_local_dir: str

    def barrier(self) -> None:
        raise NotImplementedError

    def allgather(self, obj: Any) -> List[Any]:
        raise NotImplementedError

    def post(self, tag: str, to_rank: int, payload: bytes) -> None:
        """Asynchronous byte send (partner copies, parity shipping)."""
        raise NotImplementedError

    def collect(self, tag: str, from_rank: int) -> Optional[bytes]:
        raise NotImplementedError

    def peer_local_dir(self, rank: int) -> Optional[str]:
        """Another rank's node-local storage, when reachable (recovery pulls
        partner replicas / parity shards from surviving nodes)."""
        return None


class LocalComm(Communicator):
    """Single-process binding (rank 0 of N=1). In production this is replaced
    by a jax.distributed-backed communicator with identical semantics."""

    def __init__(self, local_dir: str, rank: int = 0, world: int = 1):
        self.rank = rank
        self.world = world
        self.node_local_dir = local_dir
        os.makedirs(local_dir, exist_ok=True)
        self._mailbox: Dict[tuple, bytes] = {}

    def barrier(self) -> None:
        # single process: all local jax work must be flushed before I/O
        pass

    def allgather(self, obj: Any) -> List[Any]:
        return [obj]

    def post(self, tag: str, to_rank: int, payload: bytes) -> None:
        self._mailbox[(tag, self.rank, to_rank)] = payload

    def collect(self, tag: str, from_rank: int) -> Optional[bytes]:
        return self._mailbox.get((tag, from_rank, self.rank))


class _Exchange:
    def __init__(self):
        self.mail: Dict[tuple, bytes] = {}
        self.gathers: Dict[str, Dict[int, Any]] = {}
        self.lock = threading.Lock()


class SimComm(Communicator):
    def __init__(self, exchange: _Exchange, rank: int, world: int,
                 local_dir: str, ranks_per_node: int = 1):
        self._x = exchange
        self.rank = rank
        self.world = world
        self.ranks_per_node = ranks_per_node
        self.node_local_dir = local_dir
        os.makedirs(local_dir, exist_ok=True)

    @property
    def node_id(self) -> int:
        return self.rank // self.ranks_per_node

    def barrier(self) -> None:
        pass  # ranks execute sequentially in tests

    def allgather(self, obj: Any) -> List[Any]:
        """Sequential-test semantics: ranks run one after another, so early
        ranks see partial views (None for absent). Only the *last* rank's
        result is complete — which is the rank whose manifest write survives
        (commit is idempotent/merging), matching coordinated-store usage."""
        with self._x.lock:
            slot = self._x.gathers.setdefault("ag", {})
            slot[self.rank] = obj
            return [slot.get(r) for r in range(self.world)]

    def post(self, tag: str, to_rank: int, payload: bytes) -> None:
        with self._x.lock:
            self._x.mail[(tag, self.rank, to_rank)] = payload

    def collect(self, tag: str, from_rank: int) -> Optional[bytes]:
        with self._x.lock:
            return self._x.mail.get((tag, from_rank, self.rank))

    def peer_local_dir(self, rank: int) -> Optional[str]:
        base = os.path.dirname(self.node_local_dir)
        d = os.path.join(base, f"rank{rank}")
        return d if os.path.isdir(d) else None


class SimulatedCluster:
    """k ranks in one process; rank i's node-local storage lives under
    ``root/nodes/rank<i>``. Tests drive ranks sequentially (for_each_rank)."""

    def __init__(self, root: str, world: int, ranks_per_node: int = 1):
        self.root = root
        self.world = world
        self._x = _Exchange()
        self.comms = [
            SimComm(self._x, r, world, os.path.join(root, "nodes", f"rank{r}"),
                    ranks_per_node)
            for r in range(world)
        ]

    def kill_node(self, rank: int) -> None:
        """Simulate node loss: wipe that rank's node-local storage."""
        import shutil
        d = self.comms[rank].node_local_dir
        if os.path.isdir(d):
            shutil.rmtree(d)
        os.makedirs(d, exist_ok=True)

    def for_each_rank(self, fn: Callable[[Communicator], Any]) -> List[Any]:
        return [fn(c) for c in self.comms]
