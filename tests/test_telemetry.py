"""Telemetry plane: span tracer round-trip, the disabled no-op fast path,
metrics registry + Prometheus exposition, metrics ↔ StoreReport parity,
live /healthz /readyz /metrics endpoints flipping across a rolling fleet
hot-swap, and the chktrace summarizer."""

import json
import os
import threading
import urllib.request

import numpy as np
import pytest

from repro.telemetry import metrics as tmetrics
from repro.telemetry import trace as ttrace
from repro.telemetry.health import HealthServer, HealthState, attach_engine
from repro.tools import chktrace


@pytest.fixture(autouse=True)
def _clean_telemetry():
    """Tracer + registry are process-wide singletons; leave them as other
    tests expect them — disabled, empty, env already checked."""
    ttrace.enabled()                    # settle the one-shot env check
    ttrace.disable()
    ttrace.reset()
    tmetrics.reset()
    yield
    ttrace.disable()
    ttrace.reset()
    tmetrics.reset()


# ------------------------------------------------------------------ #
# trace: export round-trip
# ------------------------------------------------------------------ #


def test_span_export_roundtrip_balanced_monotonic_thread_tracks(tmp_path):
    ttrace.enable()
    with ttrace.span("outer", ckpt_id=7) as sp:
        assert sp.id is not None
        with ttrace.span("inner", level=4):
            ttrace.instant("marker", step=3)

    def worker():
        with ttrace.span("thread-span"):
            pass
    t = threading.Thread(target=worker, name="cp-thread")
    t.start()
    t.join()

    out = str(tmp_path / "trace.json")
    ttrace.export(out)
    doc = json.loads(open(out).read())
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]

    # B/E balanced per (pid, tid), timestamps non-decreasing per track
    by_track = {}
    for ev in events:
        by_track.setdefault((ev["pid"], ev.get("tid")), []).append(ev)
    assert len([k for k, evs in by_track.items()
                if any(e["ph"] in "BE" for e in evs)]) == 2  # two threads
    for evs in by_track.values():
        ts = [e["ts"] for e in evs if e["ph"] in ("B", "E", "i")]
        assert ts == sorted(ts)
        depth = 0
        for e in evs:
            if e["ph"] == "B":
                depth += 1
            elif e["ph"] == "E":
                depth -= 1
                assert depth >= 0
        assert depth == 0

    # every track that recorded spans is named; the process is named
    names = [e for e in events if e["ph"] == "M"]
    assert any(e["name"] == "process_name" for e in names)
    thread_tids = {e["tid"] for e in names if e["name"] == "thread_name"}
    span_tids = {e["tid"] for e in events if e["ph"] == "B"}
    assert span_tids <= thread_tids
    assert any(e["args"]["name"] == "cp-thread" for e in names
               if e["name"] == "thread_name")

    # args survive the round trip; every B carries its span id
    outer = next(e for e in events if e.get("name") == "outer")
    assert outer["args"]["ckpt_id"] == 7 and outer["args"]["span_id"] >= 1
    marker = next(e for e in events if e.get("name") == "marker")
    assert marker["ph"] == "i" and marker["args"]["step"] == 3


def test_disabled_path_is_a_shared_noop():
    sp = ttrace.span("ignored", big_arg="x" * 1000)
    assert sp is ttrace.NULL_SPAN and sp.id is None
    with sp:
        sp.event("also-ignored")
    ttrace.instant("ignored-too", step=1)
    assert ttrace.tracer().events() == []
    # and the same calls record once enabled
    ttrace.enable()
    with ttrace.span("real"):
        pass
    assert any(e.get("name") == "real" for e in ttrace.tracer().events())


def test_env_dir_protocol_and_merge(tmp_path, monkeypatch):
    d = str(tmp_path / "traces")
    os.makedirs(d)
    monkeypatch.setenv(ttrace.TRACE_DIR_ENV, d)
    # a fresh Tracer models a fresh process: lazy env check on first use
    t = ttrace.Tracer()
    with t.span("from-env"):
        pass
    assert t.enabled and t.trace_dir() == d
    assert t.flush() == os.path.join(d, f"trace-{os.getpid()}.json")
    # a second process's file (hand-written) merges in; trace.json is the
    # merged output and must not be re-consumed by a second merge
    with open(os.path.join(d, "trace-99999.json"), "w") as f:
        json.dump({"traceEvents": [
            {"ph": "i", "name": "other-proc", "ts": 1, "pid": 99999,
             "tid": 1, "args": {}}]}, f)
    merged = ttrace.merge_dir(d)
    assert merged == os.path.join(d, "trace.json")
    ev = json.load(open(merged))["traceEvents"]
    assert {e["name"] for e in ev if e.get("name")} >= {"from-env",
                                                        "other-proc"}
    n = len(ev)
    assert len(json.load(open(ttrace.merge_dir(d)))["traceEvents"]) == n


# ------------------------------------------------------------------ #
# metrics: registry + exposition
# ------------------------------------------------------------------ #


def test_metrics_registry_snapshot_and_prometheus():
    tmetrics.counter("openchk_store_total", level=4, kind="FULL").inc()
    tmetrics.counter("openchk_store_total", level=4, kind="FULL").inc(2)
    tmetrics.gauge("openchk_serve_ready", replica="r0").set(1)
    h = tmetrics.histogram("openchk_store_seconds", level=4)
    h.observe(0.003)
    h.observe(42.0)

    snap = tmetrics.snapshot()
    c = snap["openchk_store_total"]
    assert c["kind"] == "counter"
    assert c["series"] == [{"labels": {"level": "4", "kind": "FULL"},
                            "value": 3.0}]
    hs = snap["openchk_store_seconds"]["series"][0]
    assert hs["count"] == 2 and hs["sum"] == pytest.approx(42.003)
    buckets = dict((le, n) for le, n in hs["buckets"])
    assert buckets[0.005] == 1 and buckets["+Inf"] == 2  # cumulative

    text = tmetrics.to_prometheus()
    assert "# TYPE openchk_store_total counter" in text
    assert 'openchk_store_total{kind="FULL",level="4"} 3.0' in text
    assert 'openchk_serve_ready{replica="r0"} 1.0' in text
    assert '_bucket{level="4",le="0.005"} 1' in text
    assert 'openchk_store_seconds_count{level="4"} 2' in text
    assert 'le="+Inf"' in text

    # one name, one kind — forever
    with pytest.raises(TypeError, match="already registered"):
        tmetrics.gauge("openchk_store_total")


# ------------------------------------------------------------------ #
# pipeline: traced store span tree + metrics parity
# ------------------------------------------------------------------ #


def test_traced_store_span_tree_and_metrics_parity(tmp_path):
    import jax.numpy as jnp
    from repro.core.context import CheckpointConfig, CheckpointContext

    ttrace.enable()
    ctx = CheckpointContext(CheckpointConfig(
        dir=str(tmp_path / "ckpt"), backend="fti", dedicated_thread=False))
    state = {"params": {"w": jnp.asarray(
        np.arange(1 << 16, dtype=np.float32))}}
    report = ctx.store(state, id=1, level=4)
    ctx.shutdown()

    events = ttrace.tracer().events()
    names = {e["name"] for e in events if e["ph"] == "B"}
    assert {"pipeline.store", "pipeline.plan", "pipeline.pack",
            "pipeline.place", "pipeline.commit",
            "pipeline.commit.tier"} <= names
    assert "chunk.upload" in names            # the L4 objstore path
    assert sum(e["ph"] == "B" for e in events) == \
        sum(e["ph"] == "E" for e in events)

    # the report is correlated to its trace span
    store_b = next(e for e in events if e.get("name") == "pipeline.store")
    assert report.span_id == store_b["args"]["span_id"]
    assert store_b["args"]["ckpt_id"] == 1

    # and to the canonical store metrics, exactly
    assert tmetrics.counter("openchk_store_total",
                            level=4, kind="FULL").value == 1.0
    assert tmetrics.counter("openchk_store_bytes_total",
                            level=4, kind="FULL").value == \
        float(report.bytes_payload)
    hist = tmetrics.histogram("openchk_store_seconds", level=4)
    assert hist.count == 1 and hist.sum == pytest.approx(report.seconds,
                                                         abs=1e-6)
    assert tmetrics.counter("openchk_chunks_uploaded_total").value >= 1


# ------------------------------------------------------------------ #
# health: live endpoints
# ------------------------------------------------------------------ #


def _get(url):
    try:
        with urllib.request.urlopen(url, timeout=5) as r:
            return r.status, r.read().decode()
    except urllib.error.HTTPError as e:   # 503 still carries the body
        return e.code, e.read().decode()


def test_health_endpoints_flip_with_state():
    state = HealthState(name="r0")
    srv = HealthServer(state).start()
    try:
        assert _get(srv.url + "/healthz")[0] == 200
        code, body = _get(srv.url + "/readyz")
        assert code == 503 and json.loads(body)["ready"] is False
        state.set_ready(True, epoch=3, entry_id=9)
        code, body = _get(srv.url + "/readyz")
        d = json.loads(body)
        assert code == 200 and d["epoch"] == 3 and d["entry_id"] == 9
        tmetrics.counter("openchk_store_total", level=1, kind="FULL").inc()
        code, body = _get(srv.url + "/metrics")
        assert code == 200 and "openchk_store_total" in body
        assert 'openchk_serve_ready{replica="r0"} 1.0' in body
        assert _get(srv.url + "/nope")[0] == 404
    finally:
        srv.stop()


# ------------------------------------------------------------------ #
# deploy: readiness across a rolling hot-swap
# ------------------------------------------------------------------ #


def _tiny():
    import jax
    from repro.configs import get_arch
    from repro.models.zoo import build_model
    cfg = get_arch("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _publisher(tmp_path):
    from repro.core.comm import LocalComm
    from repro.core.storage import StorageConfig, StorageEngine
    cfg = StorageConfig(root=str(tmp_path / "shared"), block_bytes=256,
                        objstore_chunk_bytes=4096,
                        objstore_cdc_min_bytes=1024,
                        objstore_cdc_avg_bytes=4096,
                        objstore_cdc_max_bytes=16384)
    return StorageEngine(cfg, LocalComm(str(tmp_path / "nl-pub")))


def test_rolling_swap_drops_readiness_for_the_pull_window(tmp_path,
                                                          monkeypatch):
    """/readyz observed over real HTTP: 503 exactly while the replica is
    pulling, 200 with the new entry after the flip, and 200 again after a
    FAILED pull (the old epoch never stopped serving)."""
    from repro.core.protect import flatten_named
    from repro.objstore.client import ObjectStoreError, make_object_store
    from repro.serve.deploy import EntryPuller, FleetDeployer, Replica
    from repro.serve.engine import ServingEngine

    model, params = _tiny()
    pub = _publisher(tmp_path)
    named, _ = flatten_named({"params": params})
    state = {n: np.asarray(v) for n, v in named.items()}
    pub.store(state, ckpt_id=1, level=4)

    eng = ServingEngine(model, params, batch=2, max_len=32)
    health = attach_engine(eng, name="r0", port=0)
    url = health.server.url
    assert _get(url + "/readyz")[0] == 200        # serving local params

    seen = {}
    real_pull = EntryPuller.pull

    def spying_pull(self, entry):
        code, body = _get(url + "/readyz")
        seen["mid_pull"] = (code, json.loads(body))
        return real_pull(self, entry)

    monkeypatch.setattr(EntryPuller, "pull", spying_pull)
    store = make_object_store(
        "file:" + os.path.join(str(tmp_path / "shared"), "objstore"))
    r = Replica(name="r0", engine=eng,
                cache_root=str(tmp_path / "cache-0"), prefix="params",
                health=health)
    dep = FleetDeployer(store, [r], time_fn=lambda: 0.0)
    try:
        assert dep.poll()["action"] == "started"
        assert dep.poll()["action"] == "swapped"
        # mid-pull: not ready, and the body says why
        assert seen["mid_pull"][0] == 503
        assert seen["mid_pull"][1]["reason"] == "pulling"
        assert seen["mid_pull"][1]["target_entry"] == 1
        # after the flip: ready with the new entry (via the swap hook)
        code, body = _get(url + "/readyz")
        d = json.loads(body)
        assert code == 200 and d["entry_id"] == 1 and d["reason"] == "swapped"
        assert dep.fleet_epochs() == {"r0": 1}
        assert tmetrics.gauge("openchk_serve_ready",
                              replica="r0").value == 1.0

        # a failed pull re-asserts readiness — the old epoch still serves
        pub.store(dict(state, **{sorted(state)[0]:
                                 state[sorted(state)[0]] + 1.0}),
                  ckpt_id=2, level=4)
        assert dep.poll()["action"] == "converged"
        assert dep.poll()["action"] == "started"

        def dying_pull(self, entry):
            code, _body = _get(url + "/readyz")
            seen["mid_fail"] = code
            raise ObjectStoreError("replica killed mid-pull (injected)")

        monkeypatch.setattr(EntryPuller, "pull", dying_pull)
        st = dep.poll()
        assert st["action"] == "pinned" and seen["mid_fail"] == 503
        code, body = _get(url + "/readyz")
        d = json.loads(body)
        assert code == 200 and d["entry_id"] == 1
        assert "previous epoch" in d["reason"]
        assert dep.fleet_epochs() == {"r0": 1}    # nothing torn
    finally:
        health.server.stop()


# ------------------------------------------------------------------ #
# chktrace: the trace summarizer
# ------------------------------------------------------------------ #


def _synthetic_trace(tmp_path, with_resume=True):
    def b(name, ts, tid=1, **args):
        return {"ph": "B", "name": name, "ts": ts, "pid": 10, "tid": tid,
                "args": args}

    def e(ts, tid=1):
        return {"ph": "E", "ts": ts, "pid": 10, "tid": tid}

    ev = [
        b("pipeline.store", 0, ckpt_id=5, level=4, kind="FULL", span_id=1),
        b("pipeline.plan", 0, span_id=2), e(10),
        b("pipeline.pack", 10, span_id=3), e(40),
        b("pipeline.place", 40, tier="local", span_id=4), e(60),
        b("pipeline.place", 60, tier="pfs", span_id=5), e(160),
        b("pipeline.commit", 160, ckpt_id=5, bytes=4096, span_id=6), e(200),
        e(210),
        {"ph": "i", "name": "chaos.fault", "ts": 1_000, "pid": 20, "tid": 9,
         "args": {"site": "train.step", "mode": "exit"}},
    ]
    if with_resume:
        ev.append({"ph": "i", "name": "train.resume", "ts": 3_501_000,
                   "pid": 21, "tid": 9, "args": {"step": 6}})
    p = str(tmp_path / "synth.json")
    with open(p, "w") as f:
        json.dump({"traceEvents": ev}, f)
    return p


def test_chktrace_summary_critical_path_goodput_mttr(tmp_path, capsys):
    p = _synthetic_trace(tmp_path)
    assert chktrace.main([p, "--json", "--check", "fault-before-resume"]) == 0
    s = json.loads(capsys.readouterr().out)
    (store,) = s["stores"]
    assert store["ckpt_id"] == 5 and store["dur_us"] == 210
    assert store["dominant_stage"] == "place"
    assert store["dominant_tier"] == "pfs"
    assert store["stages_us"]["pack"] == 30
    assert s["goodput"] == [{"t_us": 40, "ckpt_id": 5, "bytes": 4096}]
    (pair,) = s["mttr"]["pairs"]
    assert pair["mttr_s"] == pytest.approx(3.5)
    assert pair["resume_step"] == 6
    assert s["processes"] == [10, 20, 21]


def test_chktrace_check_fails_without_resume(tmp_path, capsys):
    p = _synthetic_trace(tmp_path, with_resume=False)
    assert chktrace.main([p, "--check", "fault-before-resume"]) == 1
    assert "no train.resume" in capsys.readouterr().err


def test_chktrace_reads_a_trace_dir(tmp_path, capsys):
    _synthetic_trace(tmp_path)
    os.rename(str(tmp_path / "synth.json"), str(tmp_path / "trace-10.json"))
    assert chktrace.main([str(tmp_path), "--json"]) == 0
    s = json.loads(capsys.readouterr().out)
    assert s["n_events"] > 0 and s["stores"][0]["ckpt_id"] == 5
