"""Differential checkpointing engine (paper §4.2.3, FTI dCP semantics).

Per protected leaf, a 64-bit digest per ``block_bytes`` block is kept from
the previous checkpoint. On a CHK_DIFF store the new digests are computed
*on device* (Pallas blockhash on TPU; jnp oracle on CPU), the dirty map is
diffed on host (tiny), dirty blocks are compacted on device and only those
cross to the host.

Break-even guard: the paper measures differential checkpointing to pay off
below a ~95 % dirty ratio (Fig. 7). When the observed ratio exceeds
``promote_threshold`` the engine *promotes* the store to a FULL checkpoint
(cheaper, and it shortens the restore chain).

Restore: FULL base + ordered DIFF deltas are replayed into flat uint32
buffers, then bit-cast back to the leaf dtype/shape.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import dtype_to_str as dtype_str
from repro.core.formats import str_to_dtype as str_dtype
from repro.kernels import ops


@dataclass
class LeafDelta:
    path: str
    dtype: str
    shape: List[int]
    n_blocks: int
    dirty_idx: np.ndarray        # (n_dirty,) int32
    payload: np.ndarray          # (n_dirty, block_elems) uint32
    digests: np.ndarray          # (n_blocks, 2) uint32 — post-store state


@dataclass
class DiffStats:
    total_blocks: int = 0
    dirty_blocks: int = 0
    bytes_written: int = 0
    promoted_full: bool = False

    @property
    def dirty_ratio(self) -> float:
        return self.dirty_blocks / max(1, self.total_blocks)


class DiffEngine:
    def __init__(self, block_bytes: int = ops.DEFAULT_BLOCK_BYTES,
                 promote_threshold: float = 0.95):
        self.block_bytes = block_bytes
        self.promote_threshold = promote_threshold
        self._digests: Dict[str, np.ndarray] = {}

    # ------------------------------------------------------------------ #

    def reset(self) -> None:
        self._digests.clear()

    def update_digests_full(self, named: Dict[str, Any]) -> None:
        """After a FULL store: record digests so the next DIFF has a base."""
        for path, leaf in named.items():
            self._digests[path] = np.asarray(ops.blockhash(leaf, self.block_bytes))

    def compute_deltas(self, named: Dict[str, Any]
                       ) -> Tuple[Optional[List[LeafDelta]], DiffStats]:
        """→ (deltas, stats); deltas=None means "promote to FULL"."""
        stats = DiffStats()
        pending: List[Tuple[str, Any, np.ndarray, np.ndarray]] = []
        for path, leaf in named.items():
            h_new = np.asarray(ops.blockhash(leaf, self.block_bytes))
            dirty = ops.dirty_indices(h_new, self._digests.get(path))
            stats.total_blocks += h_new.shape[0]
            stats.dirty_blocks += int(dirty.shape[0])
            pending.append((path, leaf, h_new, dirty))

        if stats.dirty_ratio > self.promote_threshold:
            stats.promoted_full = True
            return None, stats

        deltas = []
        for path, leaf, h_new, dirty in pending:
            if dirty.shape[0] == 0:
                payload = np.zeros((0, self.block_bytes // 4), np.uint32)
            else:
                blocks, _ = ops.as_u32_blocks(leaf, self.block_bytes)
                payload = np.asarray(jnp.take(blocks, jnp.asarray(dirty), axis=0))
            stats.bytes_written += payload.nbytes
            deltas.append(LeafDelta(
                path=path,
                dtype=dtype_str(leaf.dtype),
                shape=list(leaf.shape),
                n_blocks=int(h_new.shape[0]),
                dirty_idx=dirty,
                payload=payload,
                digests=h_new,
            ))
        for d in deltas:
            self._digests[d.path] = d.digests
        return deltas, stats


# -------------------------------------------------------------------------- #
# restore-side replay
# -------------------------------------------------------------------------- #


def leaf_to_u32_flat(arr: np.ndarray, block_bytes: int) -> np.ndarray:
    be = block_bytes // 4
    raw = np.ascontiguousarray(arr).tobytes()
    pad = (-len(raw)) % 4
    buf = np.frombuffer(raw + b"\x00" * pad, np.uint32)
    n_blocks = max(1, -(-buf.shape[0] // be))
    out = np.zeros(n_blocks * be, np.uint32)
    out[: buf.shape[0]] = buf
    return out


def u32_flat_to_leaf(buf: np.ndarray, dtype: str, shape: List[int]) -> np.ndarray:
    dt = str_dtype(dtype)
    n_bytes = int(np.prod(shape)) * dt.itemsize
    return np.frombuffer(buf.tobytes()[:n_bytes], dtype=dt).reshape(shape).copy()


def apply_delta(buf: np.ndarray, dirty_idx: np.ndarray, payload: np.ndarray,
                block_bytes: int) -> np.ndarray:
    be = block_bytes // 4
    blocks = buf.reshape(-1, be)
    if dirty_idx.shape[0]:
        blocks[dirty_idx] = payload
    return blocks.reshape(-1)
