"""CI gate: store-path overhead must not regress vs BENCH_overhead.json.

Runs benchmarks/bench_overhead.py (fault + restart, all three backends)
and compares the measured ``overhead_ratio_*`` (OpenCHK / native wall
time, same host, same run — the noise-robust store-path metric) against
the committed baseline. Fails on a >25 % slowdown of any ratio; ratios at
or under the absolute noise floor never fail. Writes the fresh numbers as
a JSON artifact so CI uploads them per run.

Also gates the compressed-store datapoint (``Protect(compress="int8")``):

- ``compress_ratio_int8`` — int8/uncompressed payload bytes.  Nearly
  deterministic (codec math, not wall time), so the ceiling is hard: the
  tier must actually shrink the payload ~4x.
- ``compress_store_overhead_int8`` — compressed/uncompressed store wall
  time (the quantize + roundtrip-verify cost against a 4x smaller
  write).  Noise-gated like the overhead ratios, with its own floor.

And the objstore datapoint (``objstore_store_s`` wall time plus
``objstore_dedup_ratio`` — the bytes a second store after a small param
delta uploads, relative to the first; hard-gated at 0.30 since chunk
dedup is byte-deterministic).

And the sharded-store datapoint (forced-16-device mesh, 64 MiB leaf):
``sharded_store_s`` (shard-local Plan snapshot + parallel shard-file
writes) must not exceed ``gathered_store_s`` (full-tree gather) — the
no-gather path moves the same bytes while skipping the global host
buffer, so measuring slower than the gather means the store path
regressed (it currently runs ~2x faster; the gate allows the margin to
shrink to parity before failing).

Update BENCH_overhead.json in the same PR when the pipeline legitimately
changes.

Usage:
  PYTHONPATH=src:. python benchmarks/check_overhead_regression.py \
      --baseline BENCH_overhead.json --out bench-overhead.json
"""
from __future__ import annotations

import argparse
import json
import sys

from benchmarks import bench_overhead

# ratios this close to native are within the paper's envelope regardless
# of what the baseline measured — don't fail on noise around 1.0
ABS_FLOOR = 1.15
# int8 payload must stay ~4x smaller; anything above this means the codec
# stopped engaging (bytes are deterministic — no noise allowance needed)
COMPRESS_RATIO_CEILING = 0.30
# the second objstore store after a small param delta must upload <30%
# of the first store's bytes — content-addressed dedup is byte-
# deterministic (unchanged chunks hash identically), so the gate is hard:
# above it, the chunk layer stopped deduping (layout no longer stable, or
# the exists-check broke)
OBJSTORE_DEDUP_CEILING = 0.30
# compressed stores pay quantize+verify CPU against a 4x smaller write;
# the ratio's denominator (a fast uncompressed store) is noisy, so below
# this wall-time ratio the datapoint never fails — the gate exists to
# catch pathological regressions (accidental double-verify, device
# round-trips in Pack), not scheduler noise.  Tightened from 4.0 after
# the vectorized quantize pass + f32 roundtrip-error landed (measured
# ~1.5; 2.5 leaves scheduler headroom without readmitting the old cost)
COMPRESS_OVERHEAD_FLOOR = 2.5


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_overhead.json")
    ap.add_argument("--out", default=None, help="write fresh results here")
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--threshold", type=float, default=1.25,
                    help="max allowed ratio-vs-baseline slowdown factor")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        base = json.load(f)["results"]
    res = bench_overhead.run(repeats=args.repeats)
    print(json.dumps(res, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"benchmark": "bench_overhead (CI run)",
                       "baseline": args.baseline, "results": res}, f, indent=1)

    failures = []
    for key, got in sorted(res.items()):
        if not key.startswith("overhead_ratio_"):
            continue
        ref = base.get(key)
        if ref is None:
            continue
        # a baseline that got a lucky fast run (ratio < 1) must not
        # tighten the gate below "25% worse than parity": ±50% run-to-run
        # noise on shared runners would then fail an unchanged store path
        ref = max(ref, 1.0)
        if got > ABS_FLOOR and got > ref * args.threshold:
            failures.append(f"{key}: {got:.3f} vs baseline {ref:.3f} "
                            f"(> {args.threshold:.2f}x)")

    # compressed-store datapoint: hard byte ceiling + noise-gated wall time
    ratio = res.get("compress_ratio_int8")
    if ratio is not None and ratio > COMPRESS_RATIO_CEILING:
        failures.append(f"compress_ratio_int8: {ratio:.3f} > "
                        f"{COMPRESS_RATIO_CEILING} (codec not engaging)")
    ovh = res.get("compress_store_overhead_int8")
    ref = max(base.get("compress_store_overhead_int8", 1.0), 1.0)
    if (ovh is not None and ovh > COMPRESS_OVERHEAD_FLOOR
            and ovh > ref * args.threshold):
        failures.append(f"compress_store_overhead_int8: {ovh:.3f} vs "
                        f"baseline {ref:.3f} (> {args.threshold:.2f}x)")

    # objstore datapoint: hard dedup ceiling (byte-deterministic)
    ded = res.get("objstore_dedup_ratio")
    if ded is not None and ded > OBJSTORE_DEDUP_CEILING:
        failures.append(f"objstore_dedup_ratio: {ded:.3f} > "
                        f"{OBJSTORE_DEDUP_CEILING} (chunk dedup not "
                        f"engaging on the second store)")

    # sharded-store datapoint: the shard-local path must not lose to the
    # gathered path (it currently wins ~2x — parity is the hard floor)
    sh, ga = res.get("sharded_store_s"), res.get("gathered_store_s")
    if sh is not None and ga is not None and sh > ga:
        failures.append(f"sharded_store_s: {sh:.3f} > gathered_store_s "
                        f"{ga:.3f} (shard-local store path regressed)")
    if failures:
        print("store-path regression:\n" + "\n".join(failures),
              file=sys.stderr)
        return 1
    print("store-path overhead within budget vs", args.baseline)
    return 0


if __name__ == "__main__":
    sys.exit(main())
