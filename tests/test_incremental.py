"""Incremental checkpointing (§8 Future Work): parts stream in, commit is
atomic, restore is indistinguishable from a monolithic store."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.context import CheckpointConfig, CheckpointContext


def _ctx(tmp_path, name="i"):
    return CheckpointContext(CheckpointConfig(
        dir=str(tmp_path / name), backend="fti", dedicated_thread=False))


def test_incremental_store_restores_like_monolithic(tmp_path):
    state = {"params": {"w": jnp.arange(8.0)}, "opt": {"m": jnp.ones(8)},
             "step": jnp.int32(4)}
    ctx = _ctx(tmp_path)
    inc = ctx.store_begin(id=4, level=1)
    inc.add({"w": state["params"]["w"]}, prefix="params")   # ready first
    inc.add({"m": state["opt"]["m"]}, prefix="opt")         # ready later
    inc.add({"step": state["step"]})
    rep = inc.commit()
    assert rep.kind == "FULL" and rep.bytes_payload > 0
    ctx.shutdown()

    ctx2 = _ctx(tmp_path)
    template = {"params": {"w": jnp.zeros(8)}, "opt": {"m": jnp.zeros(8)},
                "step": jnp.int32(0)}
    got = ctx2.load(template)
    assert ctx2.restarted
    assert int(got["step"]) == 4
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                  np.arange(8.0))
    ctx2.shutdown()


def test_uncommitted_incremental_invisible(tmp_path):
    ctx = _ctx(tmp_path)
    inc = ctx.store_begin(id=1, level=1)
    inc.add({"w": jnp.ones(4)})
    # crash before commit: nothing restorable
    ctx2 = _ctx(tmp_path)
    got = ctx2.load({"w": jnp.zeros(4)})
    assert not ctx2.restarted
    inc.abort()
    ctx.shutdown()
    ctx2.shutdown()


def test_duplicate_part_rejected(tmp_path):
    ctx = _ctx(tmp_path)
    inc = ctx.store_begin(id=1, level=1)
    inc.add({"w": jnp.ones(4)})
    with pytest.raises(ValueError):
        inc.add({"w": jnp.zeros(4)})
    inc.abort()
    ctx.shutdown()


def test_if_clause(tmp_path):
    ctx = _ctx(tmp_path)
    assert ctx.store_begin(id=1, level=1, if_=False) is None
    ctx.shutdown()


def test_incremental_then_diff_chain(tmp_path):
    """digests from an incremental FULL base a later CHK_DIFF correctly."""
    from repro.core.context import CHK_DIFF
    base = {"x": jnp.arange(100_000, dtype=jnp.float32)}
    ctx = CheckpointContext(CheckpointConfig(
        dir=str(tmp_path / "d"), backend="fti", dedicated_thread=False,
        block_bytes=4096))
    inc = ctx.store_begin(id=1, level=1)
    inc.add(base)
    inc.commit()
    nxt = {"x": base["x"].at[5].set(-1.0)}
    rep = ctx.store(nxt, id=2, level=1, kind=CHK_DIFF)
    assert rep.kind == CHK_DIFF
    assert rep.dirty_ratio < 0.05
    ctx.shutdown()
    ctx2 = CheckpointContext(CheckpointConfig(dir=str(tmp_path / "d"),
                                              backend="fti"))
    got = ctx2.load({"x": jnp.zeros(100_000)})
    assert float(got["x"][5]) == -1.0
    ctx2.shutdown()


def test_parts_recorded_in_manifest(tmp_path):
    from repro.core import manifest as mf
    ctx = _ctx(tmp_path)
    inc = ctx.store_begin(id=9, level=1)
    inc.add({"a": jnp.ones(2)})
    inc.add({"b": jnp.zeros(3)}, prefix="later")
    inc.commit()
    eng = ctx.tcl.backend.engine
    man = mf.read_manifest(eng.local_root, 9)
    assert man["incremental"] is True
    assert man["parts"] == ["a", "later/b"]
    ctx.shutdown()
