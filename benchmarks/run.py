"""Benchmark harness — one module per paper table/figure (DESIGN.md §6).

Prints ``name,us_per_call,derived`` CSV. Sub-benchmarks:
  sloc          Tables 4–6 (programmability)     bench_sloc
  complexity    Table 1 (cyclomatic complexity)  bench_complexity
  overhead      Fig. 12 (OpenCHK vs native)      bench_overhead
  differential  Fig. 7 (dCP vs dirty ratio)      bench_differential
  async         §4.2.2 (CP-dedicated threads)    bench_async
  levels        §4.2.1 (multi-level L1–L4)       bench_levels
  roofline      §Roofline (dry-run aggregation)  bench_roofline
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset of benchmarks to run")
    ap.add_argument("--fast", action="store_true",
                    help="fewer repeats (CI mode)")
    args = ap.parse_args()

    from benchmarks import (
        bench_async,
        bench_complexity,
        bench_differential,
        bench_levels,
        bench_overhead,
        bench_roofline,
        bench_sloc,
    )

    suites = {
        "sloc": bench_sloc.rows,
        "complexity": bench_complexity.rows,
        "roofline": bench_roofline.rows,
        "levels": bench_levels.rows,
        "async": bench_async.rows,
        "differential": bench_differential.rows,
        "overhead": (lambda: bench_overhead.rows(repeats=1)) if args.fast
        else bench_overhead.rows,
    }
    chosen = args.only or list(suites)
    print("name,us_per_call,derived")
    failed = []
    for name in chosen:
        try:
            for row in suites[name]():
                n, us, derived = row
                print(f"{n},{us:.3f},{derived}")
        except Exception:  # noqa: BLE001 — report and continue
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
