"""``python -m repro.tools.chktrace <trace.json | trace-dir>`` — summarize
an OpenCHK telemetry trace.

The trace plane (repro.telemetry.trace) exports Chrome trace-event JSON;
perfetto renders it, this tool *answers questions* about it:

- **store critical path** — per ``pipeline.store`` span: total duration,
  the dominant stage (plan/pack/place/commit) and, within Place, the
  dominant tier — CRAFT's per-phase overhead accounting read straight
  off the timeline;
- **goodput timeline** — committed bytes per store over wall time
  (from the ``pipeline.commit`` span args);
- **span-measured MTTR** — for every ``chaos.fault`` → ``train.resume``
  pair across (possibly several) processes: the observed gap between the
  fault firing and the restarted worker resuming from its checkpoint,
  plus the supervisor's own ``supervisor.recovered`` samples.

``--json`` emits the machine-readable summary for CI.  ``--check
fault-before-resume`` exits nonzero unless the trace contains a
``chaos.fault`` instant *strictly before* a ``train.resume`` event — the
end-to-end assertion that a supervised kill actually produced the
fault → death → restart → resume narrative.

Pointed at a *directory*, per-process ``trace-*.json`` files are merged
in memory first (same rule as ``trace.merge_dir``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List, Optional

#: pipeline stages ranked in the critical-path breakdown
_STAGES = ("pipeline.plan", "pipeline.pack", "pipeline.place",
           "pipeline.commit")


def load_events(path: str) -> List[Dict[str, Any]]:
    """Events from a trace file, or the merged events of a trace dir."""
    if os.path.isdir(path):
        events: List[Dict[str, Any]] = []
        for fn in sorted(os.listdir(path)):
            if fn.startswith("trace") and fn.endswith(".json"):
                with open(os.path.join(path, fn), encoding="utf-8") as f:
                    events.extend(json.load(f).get("traceEvents", []))
        return events
    with open(path, encoding="utf-8") as f:
        return json.load(f).get("traceEvents", [])


def build_spans(events: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Pair B/E events per (pid, tid) stack → closed spans with
    ``dur_us`` and parent links (the trace-event nesting contract)."""
    spans: List[Dict[str, Any]] = []
    stacks: Dict[tuple, List[Dict[str, Any]]] = {}
    for ev in sorted(events, key=lambda e: e.get("ts", 0)):
        key = (ev.get("pid"), ev.get("tid"))
        if ev.get("ph") == "B":
            sp = {"name": ev.get("name"), "ts": ev["ts"], "pid": ev.get("pid"),
                  "tid": ev.get("tid"), "args": ev.get("args", {}),
                  "children": [], "dur_us": None}
            stack = stacks.setdefault(key, [])
            if stack:
                stack[-1]["children"].append(sp)
            stack.append(sp)
            spans.append(sp)
        elif ev.get("ph") == "E":
            stack = stacks.get(key)
            if stack:
                sp = stack.pop()
                sp["dur_us"] = ev["ts"] - sp["ts"]
    return [s for s in spans if s["dur_us"] is not None]


def store_critical_paths(spans: List[Dict[str, Any]]
                         ) -> List[Dict[str, Any]]:
    out = []
    for sp in spans:
        if sp["name"] != "pipeline.store":
            continue
        # per-stage totals: Place emits one span per tier, so aggregate
        stages: Dict[str, int] = {}
        for c in sp["children"]:
            if c["name"] in _STAGES:
                k = c["name"].split(".")[-1]
                stages[k] = stages.get(k, 0) + c["dur_us"]
        dom = max(stages, key=stages.get) if stages else None
        row = {"ckpt_id": sp["args"].get("ckpt_id"),
               "level": sp["args"].get("level"),
               "kind": sp["args"].get("kind"),
               "dur_us": sp["dur_us"], "stages_us": stages,
               "dominant_stage": dom}
        if dom == "place":
            tier = max((c for c in sp["children"]
                        if c["name"] == "pipeline.place"),
                       key=lambda c: c["dur_us"])
            if tier["args"].get("tier") is not None:
                row["dominant_tier"] = tier["args"]["tier"]
        out.append(row)
    return out


def goodput_timeline(spans: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """(t_us since first event, ckpt_id, bytes) per committed store."""
    commits = [s for s in spans if s["name"] == "pipeline.commit"
               and s["args"].get("bytes") is not None]
    if not commits:
        return []
    t0 = min(c["ts"] for c in commits)
    return [{"t_us": c["ts"] + c["dur_us"] - t0,
             "ckpt_id": c["args"].get("ckpt_id"),
             "bytes": c["args"].get("bytes")}
            for c in sorted(commits, key=lambda c: c["ts"])]


def mttr_from_trace(events: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Pair each ``chaos.fault`` with the next ``train.resume`` (across
    processes — wall-clock timestamps share one timebase)."""
    faults = sorted(e["ts"] for e in events
                    if e.get("ph") == "i" and e.get("name") == "chaos.fault")
    resumes = sorted((e["ts"], e.get("args", {}).get("step"))
                     for e in events
                     if e.get("ph") == "i" and e.get("name") == "train.resume")
    pairs = []
    ri = 0
    for ft in faults:
        while ri < len(resumes) and resumes[ri][0] <= ft:
            ri += 1
        if ri < len(resumes):
            pairs.append({"fault_ts": ft, "resume_ts": resumes[ri][0],
                          "resume_step": resumes[ri][1],
                          "mttr_s": (resumes[ri][0] - ft) / 1e6})
            ri += 1
    sup = [e.get("args", {}).get("mttr_s") for e in events
           if e.get("ph") == "i" and e.get("name") == "supervisor.recovered"]
    return {"n_faults": len(faults), "n_resumes": len(resumes),
            "pairs": pairs,
            "supervisor_mttr_s": [s for s in sup if s is not None]}


def summarize(path: str) -> Dict[str, Any]:
    events = load_events(path)
    spans = build_spans(events)
    instants = {}
    for e in events:
        if e.get("ph") == "i":
            instants[e["name"]] = instants.get(e["name"], 0) + 1
    return {
        "path": path,
        "n_events": len(events),
        "n_spans": len(spans),
        "processes": sorted({e.get("pid") for e in events
                             if e.get("pid") is not None}),
        "instants": instants,
        "stores": store_critical_paths(spans),
        "goodput": goodput_timeline(spans),
        "mttr": mttr_from_trace(events),
    }


def check_fault_before_resume(summary: Dict[str, Any]) -> Optional[str]:
    """→ None when the trace shows fault → resume in order, else why not."""
    m = summary["mttr"]
    if m["n_faults"] == 0:
        return "no chaos.fault instant in trace"
    if m["n_resumes"] == 0:
        return "no train.resume event in trace"
    if not m["pairs"]:
        return ("chaos.fault and train.resume present but no fault "
                "precedes a resume")
    return None


def _human(s: Dict[str, Any]) -> str:
    lines = [f"trace: {s['path']}",
             f"  events={s['n_events']} spans={s['n_spans']} "
             f"processes={s['processes']}"]
    if s["instants"]:
        marks = " ".join(f"{k}×{v}" for k, v in sorted(s["instants"].items()))
        lines.append(f"  instants: {marks}")
    for st in s["stores"]:
        extra = (f" tier={st['dominant_tier']}"
                 if st.get("dominant_tier") else "")
        lines.append(
            f"  store ckpt={st['ckpt_id']} L{st['level']} {st['kind']}: "
            f"{(st['dur_us'] or 0) / 1e3:.1f}ms "
            f"dominant={st['dominant_stage']}{extra}")
    if s["goodput"]:
        total = sum(g["bytes"] or 0 for g in s["goodput"])
        span_s = s["goodput"][-1]["t_us"] / 1e6 or 1e-9
        lines.append(f"  goodput: {len(s['goodput'])} commits, "
                     f"{total} bytes over {span_s:.2f}s")
    m = s["mttr"]
    for p in m["pairs"]:
        lines.append(f"  mttr: fault→resume(step {p['resume_step']}) "
                     f"{p['mttr_s']:.2f}s")
    if m["supervisor_mttr_s"]:
        lines.append(f"  supervisor mttr samples: "
                     f"{[round(x, 2) for x in m['supervisor_mttr_s']]}")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="summarize an OpenCHK telemetry trace")
    ap.add_argument("path", help="trace.json or a dir of trace-*.json")
    ap.add_argument("--json", action="store_true", dest="as_json")
    ap.add_argument("--check", choices=["fault-before-resume"], default=None,
                    help="exit nonzero unless the trace satisfies the "
                         "named property")
    args = ap.parse_args(argv)
    s = summarize(args.path)
    if args.as_json:
        print(json.dumps(s, indent=1, sort_keys=True))
    else:
        print(_human(s))
    if args.check == "fault-before-resume":
        why = check_fault_before_resume(s)
        if why is not None:
            print(f"[chktrace] CHECK FAILED ({args.check}): {why}",
                  file=sys.stderr)
            return 1
        print(f"[chktrace] check ok: {args.check}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
