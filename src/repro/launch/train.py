"""End-to-end training driver with OpenCHK checkpoint/restart.

Modes:
  direct:      python -m repro.launch.train --arch tinyllama-1.1b --steps 200
  supervised:  python -m repro.launch.train --supervise --inject-at 0.9 ...
               (launcher spawns the worker, injects a fault at 90 % progress,
               detects death via exit code / heartbeat timeout, restarts; the
               worker resumes from the last checkpoint via ``ctx.load`` — the
               paper's §6.1 methodology end to end)

Reduced configs run on CPU; ``--full`` uses the assigned config (TPU-scale).
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time


def worker(args) -> int:
    import jax
    from repro.configs import get_arch
    from repro.core.context import CheckpointConfig, CheckpointContext
    from repro.data.synthetic import init_data_state
    from repro.ft.failures import FaultInjector, should_inject_from_env
    from repro.models.zoo import build_model
    from repro.train.loop import LevelSchedule, LoopConfig, run_training
    from repro.train.optimizer import AdamWConfig
    from repro.train.state import init_train_state
    from repro.train.step import make_train_step

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    state = init_train_state(params, jax.random.PRNGKey(args.seed + 1),
                             init_data_state(args.seed))
    step_fn = make_train_step(
        model, AdamWConfig(total_steps=args.steps, warmup_steps=args.steps // 10),
        remat=not args.no_remat, num_microbatches=args.microbatches)

    ckpt = CheckpointContext(CheckpointConfig(
        dir=args.ckpt_dir, backend=args.backend,
        dedicated_thread=not args.no_dedicated_thread))

    inject_at = args.inject_at if args.inject_at else should_inject_from_env()
    injector = FaultInjector(args.steps, inject_at, hard=args.hard_fault) \
        if inject_at else None

    cadence = None
    if args.cadence:
        from repro.chaos.cadence import CadenceConfig, CadenceController
        cadence = CadenceController(CadenceConfig(
            prior_mtbf_s=args.cadence_mtbf))

    loop = LoopConfig(
        total_steps=args.steps,
        ckpt_every=args.ckpt_every,
        kind="DIFF" if args.differential else "FULL",
        levels=LevelSchedule(),
        heartbeat_path=os.path.join(args.ckpt_dir, "heartbeat"),
        cadence=cadence,
    )
    try:
        summary = run_training(model, step_fn, state, ckpt, loop,
                               args.batch, args.seq, injector=injector)
    finally:
        ckpt.shutdown()
    brief = {k: v for k, v in summary.items() if k != "state"}
    print(f"[train] done: {brief}")
    return 0


def supervise(args) -> int:
    """Restart launcher: run worker until success, restarting on failure."""
    from repro.ft.backoff import ExponentialBackoff
    from repro.ft.detector import Heartbeat, HeartbeatMonitor

    cmd = [sys.executable, "-m", "repro.launch.train"] + [
        a for a in sys.argv[1:] if a not in ("--supervise",)]
    env = dict(os.environ)
    if args.inject_at:
        env["OPENCHK_INJECT_AT"] = str(args.inject_at)
        cmd = [c for c in cmd if not c.startswith("--inject-at")
               and c != str(args.inject_at)]
    hb = Heartbeat(os.path.join(args.ckpt_dir, "heartbeat"))
    # same policy as the deployer's pinned-replica retries: a crash-looping
    # worker must not hammer the shared tiers at full speed
    backoff = ExponentialBackoff(base_s=args.restart_backoff,
                                 max_s=args.restart_backoff_max)
    attempts = 0
    while attempts < args.max_restarts + 1:
        attempts += 1
        print(f"[supervisor] attempt {attempts}")
        p = subprocess.Popen(cmd, env=env)
        monitor = HeartbeatMonitor(hb, timeout=args.heartbeat_timeout)
        while True:
            rc = p.poll()
            if rc is not None:
                break
            time.sleep(1.0)
            if hb.last() is not None and not monitor.alive():
                print("[supervisor] heartbeat timeout → killing worker")
                p.kill()
                rc = p.wait()
                break
        if rc == 0:
            print(f"[supervisor] success after {attempts} attempt(s)")
            return 0
        print(f"[supervisor] worker died rc={rc} "
              f"(last step {hb.last_step()}); restarting from checkpoint")
        # fault fired; clean restarts — a chaos spec left armed would kill
        # every restarted child at the same hit count (scenario runs that
        # want repeated harassment use repro.chaos.runner, not --supervise)
        env.pop("OPENCHK_INJECT_AT", None)
        env.pop("OPENCHK_CHAOS", None)
        delay = backoff.failed()
        if delay > 0:
            print(f"[supervisor] backing off {delay:.1f}s before restart")
            time.sleep(delay)
    print("[supervisor] giving up")
    return 1


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/openchk-train")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--backend", default=None, help="fti|scr|veloc (or env)")
    ap.add_argument("--differential", action="store_true")
    ap.add_argument("--full", action="store_true", help="full (TPU-size) config")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-dedicated-thread", action="store_true")
    ap.add_argument("--inject-at", type=float, default=None)
    ap.add_argument("--hard-fault", action="store_true",
                    help="os._exit instead of exception")
    ap.add_argument("--supervise", action="store_true")
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--heartbeat-timeout", type=float, default=120.0)
    ap.add_argument("--restart-backoff", type=float, default=1.0,
                    help="base seconds between restart attempts (doubles "
                         "per consecutive failure)")
    ap.add_argument("--restart-backoff-max", type=float, default=30.0)
    ap.add_argument("--cadence", action="store_true",
                    help="Daly-optimal adaptive checkpoint cadence instead "
                         "of the fixed --ckpt-every cycle")
    ap.add_argument("--cadence-mtbf", type=float, default=3600.0,
                    help="prior MTBF seconds for the cadence controller")
    args = ap.parse_args()
    os.makedirs(args.ckpt_dir, exist_ok=True)
    if args.supervise:
        return supervise(args)
    return worker(args)


if __name__ == "__main__":
    sys.exit(main())
