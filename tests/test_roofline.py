"""Roofline machinery: HLO collective parser, analytic-model invariants,
and the scan-body-once behavior that motivates the analytic model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPE_BY_NAME, get_arch
from repro.configs.base import ShapeSpec
from repro.roofline.analyze import parse_collectives
from repro.roofline.analytic import analytic_report

HLO_SAMPLE = """
HloModule test
ENTRY main {
  %p = f32[256,1024]{1,0} parameter(0)
  %ar = f32[256,1024]{1,0} all-reduce(f32[256,1024]{1,0} %p), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = bf16[1024,4096]{1,0} all-gather(bf16[1024,1024]{1,0} %x), replica_groups={{0,1,2,3}}, dimensions={1}
  %rs = f32[64,1024]{1,0} reduce-scatter(f32[256,1024]{1,0} %p), replica_groups={{0,1,2,3}}, to_apply=%add
  %cp = f32[8]{0} collective-permute(f32[8]{0} %y), source_target_pairs={{0,1}}
  %aa = f32[16,16]{1,0} all-to-all(f32[16,16]{1,0} %z), replica_groups={{0,1}}
}
"""


def test_parse_collectives_counts_and_wire():
    stats = parse_collectives(HLO_SAMPLE)
    assert stats.counts == {"all-reduce": 1, "all-gather": 1,
                            "reduce-scatter": 1, "collective-permute": 1,
                            "all-to-all": 1}
    ar = 2 * (256 * 1024 * 4) * 3 / 4
    ag = (1024 * 4096 * 2) * 3 / 4
    rs = (64 * 1024 * 4) * 3
    cp = 8 * 4
    aa = 16 * 16 * 4 * 1 / 2
    assert stats.wire_bytes == pytest.approx(ar + ag + rs + cp + aa)


def test_parse_ignores_done_ops_and_single_groups():
    txt = """
  %a = f32[8]{0} all-reduce-start(f32[8]{0} %p), replica_groups={{0,1}}
  %b = f32[8]{0} all-reduce-done(f32[8]{0} %a)
  %c = f32[8]{0} all-reduce(f32[8]{0} %p), replica_groups={{0}}
"""
    stats = parse_collectives(txt)
    assert stats.counts.get("all-reduce", 0) == 1   # -start only, group>1


def test_scan_body_counted_once():
    """The motivating XLA behavior: cost_analysis sees a while body once."""
    w = jnp.ones((64, 64))

    def body(h, _):
        return h @ w, None

    def scan5(h):
        return jax.lax.scan(body, h, None, length=5)[0]

    def unroll5(h):
        for _ in range(5):
            h = h @ w
        return h

    h = jnp.ones((64, 64))
    def flops(f):
        ca = jax.jit(f).lower(h).compile().cost_analysis()
        if isinstance(ca, list):        # older jax: one entry per module
            ca = ca[0]
        return ca["flops"]
    f_scan = flops(scan5)
    f_unroll = flops(unroll5)
    assert f_unroll == pytest.approx(5 * f_scan, rel=0.01)


# --------------------------- analytic invariants --------------------------- #

ARCHS = ["tinyllama-1.1b", "mixtral-8x7b", "rwkv6-3b", "whisper-small",
         "jamba-1.5-large-398b", "minicpm3-4b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_terms_positive_and_finite(arch):
    cfg = get_arch(arch)
    for shape in cfg.shapes():
        r = analytic_report(cfg, shape, dp=16, tp=16)
        for k in ("t_compute", "t_memory", "t_collective"):
            assert np.isfinite(r[k]) and r[k] >= 0, (arch, shape.name, k)
        assert r["flops_per_device"] > 0
        assert 0 < r["useful_flops_ratio"] < 3, (arch, shape.name,
                                                 r["useful_flops_ratio"])


def test_flops_scale_with_batch():
    cfg = get_arch("tinyllama-1.1b")
    s1 = ShapeSpec("t", "train", 4096, 256)
    s2 = ShapeSpec("t", "train", 4096, 512)
    r1 = analytic_report(cfg, s1, dp=16, tp=16)
    r2 = analytic_report(cfg, s2, dp=16, tp=16)
    assert r2["flops_per_device"] == pytest.approx(
        2 * r1["flops_per_device"], rel=0.01)


def test_no_collectives_on_single_device():
    cfg = get_arch("tinyllama-1.1b")
    r = analytic_report(cfg, SHAPE_BY_NAME["train_4k"], dp=1, tp=1)
    assert r["wire_bytes_per_device"] == 0.0


def test_zero1_wire_equal_fp32_smaller_bf16():
    """ZeRO-1 = RS(grad fp32) + AG(params): equals all-reduce wire at fp32
    params (4+4 vs 2·4 bytes/param); beats it with bf16 params (4+2 < 8)."""
    import dataclasses
    cfg = get_arch("tinyllama-1.1b")
    sh = SHAPE_BY_NAME["train_4k"]
    base = analytic_report(cfg, sh, dp=16, tp=16)
    z1 = analytic_report(cfg, sh, dp=16, tp=16, zero1=True)
    assert z1["wire_bytes_per_device"] == pytest.approx(
        base["wire_bytes_per_device"])
    bf = dataclasses.replace(cfg, param_dtype="bfloat16")
    z1b = analytic_report(bf, sh, dp=16, tp=16, zero1=True)
    assert z1b["wire_bytes_per_device"] < base["wire_bytes_per_device"]


def test_swa_cheaper_than_full_attention_at_32k():
    """Mixtral's sliding window must cut prefill attention flops."""
    import dataclasses
    cfg = get_arch("mixtral-8x7b")
    full = dataclasses.replace(cfg, sliding_window=None)
    sh = SHAPE_BY_NAME["prefill_32k"]
    r_swa = analytic_report(cfg, sh, dp=16, tp=16)
    r_full = analytic_report(full, sh, dp=16, tp=16)
    assert r_swa["flops_per_device"] < 0.75 * r_full["flops_per_device"]


def test_remat_adds_compute_removes_nothing_else():
    cfg = get_arch("llama3.2-3b")
    sh = SHAPE_BY_NAME["train_4k"]
    r_on = analytic_report(cfg, sh, dp=16, tp=16, remat=True)
    r_off = analytic_report(cfg, sh, dp=16, tp=16, remat=False)
    assert r_on["flops_per_device"] == pytest.approx(
        4 / 3 * r_off["flops_per_device"], rel=0.05)


def test_model_flops_moe_counts_active_only():
    cfg = get_arch("mixtral-8x7b")
    n_active = cfg.flops_param_count()
    # mixtral: ~13B active of ~47B total
    total = cfg.param_count(active_only=False)
    assert n_active < 0.35 * total
