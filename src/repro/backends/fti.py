"""FTI-like backend: memory-mode, multi-level L1–L4, differential
checkpointing, CP-dedicated threads (the feature superset — §3 of the paper).

Native API mirrors FTI: ``protect / status / recover / checkpoint /
finalize``. Protect registers (id, name, array); checkpoint writes all
protected regions; recover returns them by id after a restart.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.backends.base import Backend
from repro.core.async_engine import CPDedicatedThread
from repro.core.comm import Communicator
from repro.core.storage import CHK_DIFF, CHK_FULL, StorageConfig, StoreReport


class FTIBackend(Backend):
    name = "fti"
    supports_diff = True
    supports_dedicated_thread = True
    max_level = 4

    def __init__(self, cfg: StorageConfig, comm: Communicator,
                 dedicated_thread: bool = True):
        super().__init__(cfg, comm)
        self._protected: Dict[int, Tuple[str, np.ndarray]] = {}
        self._cp = CPDedicatedThread() if dedicated_thread else None

    # ----------------------- native FTI-style API ---------------------- #

    def protect(self, pid: int, name: str, arr) -> None:
        self._protected[pid] = (name, arr)

    def status(self) -> bool:
        """FTI_Status: is there anything to recover?"""
        return self.engine.load_latest() is not None

    def recover(self) -> Dict[int, np.ndarray]:
        """FTI_Recover: refill protected regions from the newest checkpoint."""
        got = self.engine.load_latest()
        if got is None:
            raise RuntimeError("FTI: no checkpoint to recover")
        named, _ = got
        out: Dict[int, np.ndarray] = {}
        for pid, (name, _old) in self._protected.items():
            key = f"p{pid}/{name}"
            if key not in named:
                raise RuntimeError(f"FTI: protected id {pid} ({name}) missing")
            out[pid] = named[key]
        self.stats["loads"] += 1
        return out

    def checkpoint(self, ckpt_id: int, level: int,
                   differential: bool = False) -> Optional[StoreReport]:
        named = {f"p{pid}/{name}": np.asarray(arr)
                 for pid, (name, arr) in self._protected.items()}
        kind = CHK_DIFF if differential else CHK_FULL
        if self._cp is not None:
            self._cp.check_errors()
            self._cp.submit(
                ckpt_id, lambda: self._store_sync(named, ckpt_id, level, kind))
            return None
        return self._store_sync(named, ckpt_id, level, kind)

    def checkpoint_wait(self) -> None:
        if self._cp is not None:
            self._cp.wait()
            self._cp.check_errors()

    def finalize(self) -> None:
        if self._cp is not None:
            self._cp.shutdown()

    # ----------------------- TCL uniform surface ----------------------- #

    def _store_sync(self, named, ckpt_id, level, kind) -> StoreReport:
        rep = self.engine.store(named, ckpt_id, level, kind,
                                diff_supported=True)
        self.stats["stores"] += 1
        self.stats["bytes"] += rep.bytes_payload
        return rep

    def tcl_store(self, named, ckpt_id, level, kind) -> Optional[StoreReport]:
        if self._cp is not None:
            self._cp.check_errors()
            self._cp.submit(
                ckpt_id, lambda: self._store_sync(named, ckpt_id, level, kind))
            return None
        return self._store_sync(named, ckpt_id, level, kind)

    def tcl_load(self):
        self.tcl_wait()
        got = self.engine.load_latest()
        if got is None:
            return None
        self.stats["loads"] += 1
        return got[0]

    def tcl_wait(self) -> None:
        self.checkpoint_wait()

    def tcl_finalize(self) -> None:
        self.finalize()
