"""Pure-JAX AdamW + learning-rate schedules (no optax in this container)."""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    count: jnp.ndarray           # scalar int32
    mu: Any                      # first moment (pytree, fp32)
    nu: Any                      # second moment (pytree, fp32)


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup → cosine decay to min_lr_ratio·lr."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree: Any, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), norm


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return AdamWState(
        count=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def adamw_update(cfg: AdamWConfig, grads: Any, state: AdamWState, params: Any
                 ) -> Tuple[Any, AdamWState, dict]:
    """Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    count = state.count + 1
    lr = lr_schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g, state.nu, grads)

    def upd(p, m, v):
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                              # decay matrices only
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(count, mu, nu), {"lr": lr, "grad_norm": gnorm}
