"""CHK5 container: round trips, integrity, partial reads."""
import io
import os

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # container without hypothesis: tiny shim
    from _hypothesis_fallback import given, settings, st

from repro.core.formats import (
    CHK5CorruptionError,
    CHK5Reader,
    CHK5Writer,
    dtype_to_str,
    str_to_dtype,
)

DTYPES = ["<f4", "<f8", "<i4", "<i8", "<u4", "<u2", "|i1"]


def test_roundtrip_basic(tmp_path):
    p = str(tmp_path / "a.chk5")
    a = np.arange(24, dtype=np.float32).reshape(2, 3, 4)
    with CHK5Writer(p) as w:
        w.write_dataset("data/x", a, {"k": 1})
        w.write_bytes("raw/blob", b"\x00\x01hello")
        w.set_attrs("", {"id": 3, "kind": "FULL"})
    r = CHK5Reader(p, verify=True)
    assert r.datasets() == ["data/x", "raw/blob"]
    assert np.array_equal(r.read_dataset("data/x"), a)
    assert r.read_bytes("raw/blob") == b"\x00\x01hello"
    assert r.attrs("")["kind"] == "FULL"
    assert r.info("data/x")["attrs"] == {"k": 1}
    r.close()


def test_scalar_and_empty(tmp_path):
    p = str(tmp_path / "s.chk5")
    with CHK5Writer(p) as w:
        w.write_dataset("s", np.uint32(7))
        w.write_dataset("e", np.zeros((0, 4), np.float32))
    r = CHK5Reader(p)
    assert r.read_dataset("s").shape == ()
    assert r.read_dataset("s") == 7
    assert r.read_dataset("e").shape == (0, 4)


def test_bfloat16_roundtrip(tmp_path):
    ml_dtypes = pytest.importorskip("ml_dtypes")
    p = str(tmp_path / "b.chk5")
    a = np.arange(8).astype(ml_dtypes.bfloat16)
    with CHK5Writer(p) as w:
        w.write_dataset("b", a)
    r = CHK5Reader(p)
    got = r.read_dataset("b")
    assert got.dtype == np.dtype(ml_dtypes.bfloat16)
    assert np.array_equal(got.astype(np.float32), a.astype(np.float32))


def test_corruption_detected(tmp_path):
    p = str(tmp_path / "c.chk5")
    a = np.random.RandomState(0).randn(64).astype(np.float32)
    with CHK5Writer(p) as w:
        w.write_dataset("x", a)
    raw = bytearray(open(p, "rb").read())
    raw[20] ^= 0xFF                    # flip a payload byte
    open(p, "wb").write(raw)
    r = CHK5Reader(p)
    with pytest.raises(CHK5CorruptionError):
        r.read_dataset("x")


def test_truncation_detected(tmp_path):
    p = str(tmp_path / "t.chk5")
    with CHK5Writer(p) as w:
        w.write_dataset("x", np.zeros(16, np.float32))
    raw = open(p, "rb").read()
    open(p, "wb").write(raw[: len(raw) // 2])
    with pytest.raises((CHK5CorruptionError, Exception)):
        CHK5Reader(p)


def test_partial_range_read(tmp_path):
    p = str(tmp_path / "r.chk5")
    a = np.arange(1000, dtype=np.int64)
    with CHK5Writer(p) as w:
        w.write_dataset("x", a)
    r = CHK5Reader(p)
    assert np.array_equal(r.read_range("x", 100, 50), a[100:150])


def test_memory_file():
    buf = io.BytesIO()
    w = CHK5Writer.__new__(CHK5Writer)   # file-object writer path
    # simpler: write to bytes via temp then read through BytesIO
    import tempfile
    with tempfile.NamedTemporaryFile(suffix=".chk5", delete=False) as f:
        path = f.name
    with CHK5Writer(path) as w:
        w.write_dataset("x", np.ones(4))
    r = CHK5Reader(io.BytesIO(open(path, "rb").read()))
    assert np.array_equal(r.read_dataset("x"), np.ones(4))
    os.unlink(path)


@settings(max_examples=25, deadline=None)
@given(
    dtype=st.sampled_from(DTYPES),
    shape=st.lists(st.integers(1, 8), min_size=0, max_size=3),
    seed=st.integers(0, 2**31 - 1),
)
def test_roundtrip_property(tmp_path_factory, dtype, shape, seed):
    rng = np.random.RandomState(seed)
    a = np.asarray(rng.randn(*shape) * 100).astype(np.dtype(dtype))
    p = str(tmp_path_factory.mktemp("h") / "x.chk5")
    with CHK5Writer(p) as w:
        w.write_dataset("x", a)
    r = CHK5Reader(p, verify=True)
    got = r.read_dataset("x")
    assert got.dtype == a.dtype and got.shape == a.shape
    assert np.array_equal(got, a)
    r.close()


def test_dtype_str_helpers():
    assert str_to_dtype(dtype_to_str(np.float32)) == np.float32
    import ml_dtypes
    assert str_to_dtype(dtype_to_str(ml_dtypes.bfloat16)) == np.dtype(
        ml_dtypes.bfloat16)
