"""End-to-end restart semantics: exactly-once data, bitwise resume parity,
directive clauses, fault-injection loop."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.context import (
    CHK_DIFF,
    CheckpointConfig,
    CheckpointContext,
    Protect,
)
from repro.data.synthetic import init_data_state
from repro.ft.failures import FaultInjector, SimulatedFault
from repro.models.zoo import build_model
from repro.train.loop import LevelSchedule, LoopConfig, run_training
from repro.train.optimizer import AdamWConfig
from repro.train.state import init_train_state
from repro.train.step import make_train_step


def _setup(arch="tinyllama-1.1b", seed=0):
    cfg = get_arch(arch).reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(seed))
    state = init_train_state(params, jax.random.PRNGKey(seed + 1),
                             init_data_state(seed))
    step = make_train_step(m, AdamWConfig(total_steps=20, warmup_steps=2),
                           remat=False)
    return cfg, m, state, step


def _leaves(state):
    return jax.tree.leaves(state.params)


def test_resume_is_bitwise_identical(tmp_path):
    """Train 10 straight vs train → crash at 7 → restore → finish: identical
    final params (exactly-once data via the in-state cursor)."""
    cfg, m, state0, step = _setup()
    loop = LoopConfig(total_steps=10, ckpt_every=3,
                      levels=LevelSchedule(l2_every=0, l3_every=0, l4_every=0))

    # run A: straight through
    ctxa = CheckpointContext(CheckpointConfig(
        dir=str(tmp_path / "a"), backend="fti", dedicated_thread=False))
    outA = run_training(m, step, state0, ctxa, loop, 2, 32,
                        log=lambda *_: None)
    ctxa.shutdown()

    # run B: fault at step 7 → restart → resume from checkpoint at step 6
    ctxb = CheckpointContext(CheckpointConfig(
        dir=str(tmp_path / "b"), backend="fti", dedicated_thread=False))
    inj = FaultInjector(total_steps=10, at_progress=0.7)
    with pytest.raises(SimulatedFault):
        run_training(m, step, state0, ctxb, loop, 2, 32, injector=inj,
                     log=lambda *_: None)
    ctxb.shutdown()
    ctxb2 = CheckpointContext(CheckpointConfig(
        dir=str(tmp_path / "b"), backend="fti", dedicated_thread=False))
    outB = run_training(m, step, state0, ctxb2, loop, 2, 32,
                        log=lambda *_: None)
    ctxb2.shutdown()
    assert outB["restarted"]

    for a, b in zip(_leaves(outA["state"]), _leaves(outB["state"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(outA["state"].data_state.position) == \
        int(outB["state"].data_state.position) == 10


def test_training_loop_advances_data_cursor(tmp_path):
    cfg, m, state, step = _setup()
    loop = LoopConfig(total_steps=4, ckpt_every=2,
                      levels=LevelSchedule(l2_every=0, l3_every=0, l4_every=0))
    ctx = CheckpointContext(CheckpointConfig(
        dir=str(tmp_path / "c"), backend="fti", dedicated_thread=False))
    run_training(m, step, state, ctx, loop, 2, 32, log=lambda *_: None)
    ctx.shutdown()
    ctx2 = CheckpointContext(CheckpointConfig(
        dir=str(tmp_path / "c"), backend="fti", dedicated_thread=False))
    restored = ctx2.load(state)
    assert ctx2.restarted
    assert int(restored.step) == 4
    assert int(restored.data_state.position) == 4
    ctx2.shutdown()


def test_if_clause_switches_off(tmp_path):
    state = {"x": jnp.ones(4)}
    ctx = CheckpointContext(CheckpointConfig(dir=str(tmp_path / "i"),
                                             backend="fti",
                                             dedicated_thread=False))
    assert ctx.store(state, id=1, level=1, if_=False) is None
    assert ctx.stats["stores"] == 0
    got = ctx.load(state, if_=False)
    assert got is state
    ctx.shutdown()


def test_id_level_mandatory():
    ctx_cls = CheckpointContext
    import inspect
    sig = inspect.signature(ctx_cls.store)
    assert sig.parameters["id"].default is inspect.Parameter.empty
    assert sig.parameters["level"].default is inspect.Parameter.empty


def test_selectors_protect_subtree(tmp_path):
    state = {"params": {"w": jnp.arange(4.0)}, "opt": {"m": jnp.zeros(4)},
             "step": jnp.int32(3)}
    ctx = CheckpointContext(CheckpointConfig(dir=str(tmp_path / "s"),
                                             backend="fti",
                                             dedicated_thread=False))
    ctx.protect(Protect("params/**"), Protect("step"))
    ctx.store(state, id=1, level=1)
    ctx.shutdown()
    ctx2 = CheckpointContext(CheckpointConfig(dir=str(tmp_path / "s"),
                                              backend="fti",
                                              dedicated_thread=False))
    ctx2.protect(Protect("params/**"), Protect("step"))
    template = {"params": {"w": jnp.zeros(4)}, "opt": {"m": jnp.ones(4) * 9},
                "step": jnp.int32(0)}
    got = ctx2.load(template)
    assert np.array_equal(np.asarray(got["params"]["w"]), np.arange(4.0))
    assert int(got["step"]) == 3
    # unprotected leaf keeps the template value
    assert np.array_equal(np.asarray(got["opt"]["m"]), np.ones(4) * 9)
    ctx2.shutdown()


def test_store_after_shutdown_raises(tmp_path):
    ctx = CheckpointContext(CheckpointConfig(dir=str(tmp_path / "z"),
                                             backend="fti",
                                             dedicated_thread=False))
    ctx.shutdown()
    with pytest.raises(RuntimeError):
        ctx.store({"x": jnp.ones(2)}, id=1, level=1)


def test_diff_then_restart_loop(tmp_path):
    """Differential checkpoints through the full training loop + restart."""
    cfg, m, state, step = _setup()
    loop = LoopConfig(total_steps=6, ckpt_every=2, kind=CHK_DIFF,
                      levels=LevelSchedule(l2_every=0, l3_every=0, l4_every=0))
    ctx = CheckpointContext(CheckpointConfig(
        dir=str(tmp_path / "d"), backend="fti", dedicated_thread=False))
    inj = FaultInjector(total_steps=6, at_progress=0.9)
    with pytest.raises(SimulatedFault):
        run_training(m, step, state, ctx, loop, 2, 32, injector=inj,
                     log=lambda *_: None)
    ctx.shutdown()
    ctx2 = CheckpointContext(CheckpointConfig(
        dir=str(tmp_path / "d"), backend="fti", dedicated_thread=False))
    out = run_training(m, step, state, ctx2, loop, 2, 32, log=lambda *_: None)
    assert out["restarted"] and out["final_step"] == 6
    ctx2.shutdown()
